//! Experiment coordinator: glues compression, SRA, evaluation and DSE.
//!
//! The coordinator owns the PJRT engine, the per-pair models and corpora,
//! and two caches; everything the figure runners ([`figures`]) and the
//! examples do goes through it. Per-layer compression jobs fan out on the
//! thread pool; BLEU evaluations are memoized by configuration fingerprint
//! (the SRA search revisits allocations); Algorithm 1 runs are memoized
//! per `(pair, wl)` by the incremental compression cache
//! (`compress::incremental`), so every SvdIter/SvdIterRanks configuration
//! after the first is a rank-truncation query instead of a recompression.
//!
//! Everything touching the PJRT runtime (the coordinator itself, figures)
//! needs the `pjrt` feature; the method/dispatch layer ([`methods`]),
//! report emission, the backend-agnostic serving loop ([`serve`]) and the
//! continuous-batching scheduler ([`scheduler`]) stay in the default
//! build — `serve_demo_native` runs the full request path on the
//! pure-Rust engine under either [`Batcher`]: the static
//! group-decode-respond loop, or [`ContinuousBatcher`]'s slot-addressed
//! retire/admit/step rounds that keep the KV-cached decode engine full
//! under dynamic load.
//!
//! The serving stack is fault-tolerant by construction ([`fault`]):
//! requests carry [`RequestLimits`] (step deadlines, token budgets) and
//! answer through one-shot [`response_channel`]s with a typed
//! [`ServeError`] taxonomy — admission overload sheds, deadlines expire
//! slots deterministically, client disconnects cancel orphaned work,
//! engine panics are isolated per slot, and a [`ShutdownSignal`] drains
//! the loop with balanced accounting.

#[cfg(feature = "pjrt")]
pub mod figures;
pub mod fault;
mod methods;
pub mod report;
pub mod scheduler;
mod serve;

pub use fault::{
    drain_ready, response_channel, AttributedError, RequestLimits, Response, ResponseRx,
    ResponseTx, ServeError, ServeResult, ShutdownSignal, StreamEvent, TimedRecv,
};
pub use methods::{compress_model_from, CompressedModel, Method};
pub use scheduler::{Batcher, BatcherStats, Completion, ContinuousBatcher};
#[cfg(feature = "pjrt")]
pub use serve::serve_bank;
#[cfg(feature = "pjrt")]
pub use serve::serve_demo;
pub use serve::{
    pack_rows, run_demo, run_demo_continuous, serve_demo_native, serve_loop,
    serve_loop_continuous, Request, ServeConfig, ServeStats, ServeTuning,
};

#[cfg(feature = "pjrt")]
use std::collections::{BTreeMap, HashMap};
#[cfg(feature = "pjrt")]
use std::sync::{Arc, Mutex};

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use crate::compress::{CompressedLinear, IncrementalItera};
#[cfg(feature = "pjrt")]
use crate::config::ExpConfig;
#[cfg(feature = "pjrt")]
use crate::eval::{evaluate_bleu, Corpus};
#[cfg(feature = "pjrt")]
use crate::model::{Manifest, PairModel};
#[cfg(feature = "pjrt")]
use crate::quant::WordLen;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Mode, PjrtBackend, TranslateSession};

/// Orchestrates the full ITERA-LLM pipeline against the built artifacts.
#[cfg(feature = "pjrt")]
pub struct Coordinator {
    pub manifest: Manifest,
    pub engine: Engine,
    pub cfg: ExpConfig,
    models: BTreeMap<String, PairModel>,
    corpora: BTreeMap<String, Corpus>,
    calib: BTreeMap<String, Corpus>,
    bleu_cache: Mutex<HashMap<u64, f64>>,
    /// Incremental Algorithm 1 cache: one full-rank run per
    /// `(pair, wl, layer)`, truncation queries afterwards.
    itera_caches: Mutex<HashMap<(String, WordLen), Arc<Vec<IncrementalItera>>>>,
    /// Itera-family compression requests per `(pair, wl)` — the cache is
    /// only built from the second request on, so a one-shot compression
    /// never pays the full-rank fill.
    itera_uses: Mutex<HashMap<(String, WordLen), u32>>,
}

#[cfg(feature = "pjrt")]
impl Coordinator {
    /// Load manifest, weights and corpora for every trained pair and
    /// create the PJRT engine.
    pub fn new(cfg: ExpConfig) -> Result<Coordinator> {
        let manifest = Manifest::load(Manifest::default_dir())
            .context("loading artifacts (run `make artifacts`)")?;
        let engine = Engine::cpu()?;
        let mut models = BTreeMap::new();
        let mut corpora = BTreeMap::new();
        let mut calib = BTreeMap::new();
        for (pair, info) in &manifest.pairs {
            models.insert(pair.clone(), PairModel::load(&manifest, pair)?);
            corpora.insert(pair.clone(), Corpus::load(&info.corpus)?);
            calib.insert(pair.clone(), Corpus::load(&info.calib)?);
        }
        Ok(Coordinator {
            manifest,
            engine,
            cfg,
            models,
            corpora,
            calib,
            bleu_cache: Mutex::new(HashMap::new()),
            itera_caches: Mutex::new(HashMap::new()),
            itera_uses: Mutex::new(HashMap::new()),
        })
    }

    pub fn model(&self, pair: &str) -> &PairModel {
        &self.models[pair]
    }

    pub fn pairs(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Opportunistic cache lookup: returns the `(pair, wl)` cache when it
    /// already exists, or — from the *second* itera-family request for
    /// that key on — builds it. The first request returns `None` so a
    /// one-shot compression keeps the cheap direct rank-`r` path instead
    /// of paying L full-rank decompositions; every search/sweep pattern
    /// (SRA oracle, fig 7/8/11 grids) hits the key repeatedly and gets
    /// the cache from its second configuration onward.
    fn itera_cache_opportunistic(
        &self,
        pair: &str,
        wl: WordLen,
    ) -> Option<Arc<Vec<IncrementalItera>>> {
        let key = (pair.to_string(), wl);
        if let Some(c) = self.itera_caches.lock().unwrap().get(&key) {
            return Some(c.clone());
        }
        let uses = {
            let mut map = self.itera_uses.lock().unwrap();
            let n = map.entry(key).or_insert(0);
            *n += 1;
            *n
        };
        if uses >= 2 {
            Some(self.itera_cache(pair, wl))
        } else {
            None
        }
    }

    /// Drop all incremental compression caches (and their use counters),
    /// releasing the retained full-rank factors. Long-lived coordinators
    /// can call this between sweeps over different word lengths.
    pub fn drop_itera_caches(&self) {
        self.itera_caches.lock().unwrap().clear();
        self.itera_uses.lock().unwrap().clear();
    }

    /// The incremental Algorithm 1 cache for `(pair, wl)`, filling it (in
    /// parallel, one full-rank decomposition per layer) on first use.
    pub fn itera_cache(&self, pair: &str, wl: WordLen) -> Arc<Vec<IncrementalItera>> {
        let key = (pair.to_string(), wl);
        if let Some(c) = self.itera_caches.lock().unwrap().get(&key) {
            return c.clone();
        }
        // Fill outside the lock: decompositions are slow and deterministic,
        // so a racing duplicate fill is wasteful but harmless (first insert
        // wins).
        let model = self.model(pair);
        let linears = &self.manifest.linears;
        let built: Vec<IncrementalItera> =
            crate::util::pool::par_map(linears.len(), self.cfg.workers, |i| {
                IncrementalItera::compress(model.linear(&linears[i].name), wl)
            });
        let built = Arc::new(built);
        self.itera_caches
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| built.clone())
            .clone()
    }

    /// Compress every linear of `pair` with `method` (cache-backed for the
    /// Algorithm 1 family, parallel per layer otherwise).
    pub fn compress(&self, pair: &str, method: &Method) -> CompressedModel {
        methods::compress_model(self, pair, method)
    }

    /// BLEU of a compressed model on the held-out test set.
    pub fn bleu_test(&self, pair: &str, cm: &CompressedModel) -> Result<f64> {
        self.bleu_on(pair, cm, &self.corpora[pair], self.cfg.eval_sentences)
    }

    /// BLEU on the calibration subset (the SRA oracle), memoized.
    pub fn bleu_calib(&self, pair: &str, cm: &CompressedModel) -> Result<f64> {
        let key = cm.fingerprint(pair);
        if let Some(&v) = self.bleu_cache.lock().unwrap().get(&key) {
            return Ok(v);
        }
        let v = self.bleu_on(pair, cm, &self.calib[pair], self.cfg.calib_sentences)?;
        self.bleu_cache.lock().unwrap().insert(key, v);
        Ok(v)
    }

    fn bleu_on(
        &self,
        pair: &str,
        cm: &CompressedModel,
        corpus: &Corpus,
        limit: usize,
    ) -> Result<f64> {
        let mode = cm.mode();
        let session = TranslateSession::new(&self.engine, &self.manifest, mode)?;
        let bank = session.build_bank(&self.models[pair], &cm.layers, cm.act_wl)?;
        let backend = PjrtBackend::new(session, bank);
        let d = evaluate_bleu(&backend, corpus, &self.manifest.model, limit)?;
        Ok(d.score)
    }

    /// FP32 reference BLEU (uncompressed, FP32 activations).
    pub fn bleu_fp32(&self, pair: &str) -> Result<f64> {
        let session = TranslateSession::new(&self.engine, &self.manifest, Mode::Dense)?;
        let bank = session.build_bank(&self.models[pair], &BTreeMap::new(), None)?;
        let backend = PjrtBackend::new(session, bank);
        let d = evaluate_bleu(
            &backend,
            &self.corpora[pair],
            &self.manifest.model,
            self.cfg.eval_sentences,
        )?;
        Ok(d.score)
    }

    /// Compress a single layer by manifest index (SRA inner loop). For the
    /// Algorithm 1 family this is a truncation query against the
    /// incremental cache once the `(pair, wl)` key has warmed up.
    pub fn compress_layer(
        &self,
        pair: &str,
        idx: usize,
        method: &Method,
        rank: usize,
    ) -> CompressedLinear {
        if let Method::SvdIter { wl, .. } | Method::SvdIterRanks { wl, .. } = method {
            if let Some(cache) = self.itera_cache_opportunistic(pair, *wl) {
                return cache[idx].query(rank);
            }
        }
        let l = &self.manifest.linears[idx];
        methods::compress_one(self.models[pair].linear(&l.name), method, rank)
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn coordinator() -> Option<Coordinator> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Coordinator::new(ExpConfig::fast()).unwrap())
    }

    #[test]
    fn quant_only_pipeline_end_to_end() {
        let Some(c) = coordinator() else { return };
        let cm = c.compress("en-de", &Method::QuantOnly { wl: 8 });
        assert_eq!(cm.layers.len(), c.manifest.linears.len());
        let bleu = c.bleu_test("en-de", &cm).unwrap();
        assert!(bleu > 80.0, "W8A8 BLEU {bleu}");
        let (ratio, _nops) = cm.cost(&c.manifest, 512);
        assert!((ratio - 4.0).abs() < 0.3, "W8 ratio {ratio}");
    }

    #[test]
    fn calib_cache_hits() {
        let Some(c) = coordinator() else { return };
        let cm = c.compress("en-de", &Method::QuantOnly { wl: 6 });
        let a = c.bleu_calib("en-de", &cm).unwrap();
        let t0 = std::time::Instant::now();
        let b = c.bleu_calib("en-de", &cm).unwrap();
        assert_eq!(a, b);
        assert!(t0.elapsed().as_millis() < 50, "second call must be cached");
    }

    #[test]
    fn itera_cache_fills_once_per_pair_wl() {
        let Some(c) = coordinator() else { return };
        let first = c.itera_cache("en-de", 4);
        let again = c.itera_cache("en-de", 4);
        assert!(Arc::ptr_eq(&first, &again), "same Arc on repeat lookup");
        // Two different uniform fractions share the same cache fill.
        let a = c.compress("en-de", &Method::SvdIter { wl: 4, rank_frac: 0.25 });
        let b = c.compress("en-de", &Method::SvdIter { wl: 4, rank_frac: 0.5 });
        assert!(a.ranks(&c.manifest).iter().sum::<usize>()
            < b.ranks(&c.manifest).iter().sum::<usize>());
    }
}
