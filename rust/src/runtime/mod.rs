//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many.
//!
//! This is the request-path boundary of the three-layer architecture: the
//! Python compile path ran once at build time; from here on everything is
//! Rust + the PJRT C API (`xla` crate over xla_extension 0.5.1, CPU
//! plugin). HLO **text** is the interchange format — `HloModuleProto::
//! from_text_file` reassigns instruction ids, sidestepping the 64-bit-id
//! protos jax>=0.5 emits that this XLA build rejects.
//!
//! Weight arguments are uploaded to device buffers **once per compression
//! configuration** ([`ArgBank`]); each translate call then swaps only the
//! source-token buffer — the same weights-stay-resident discipline a real
//! accelerator deployment would use, and the single biggest perf lever on
//! the eval loop (see EXPERIMENTS.md §Perf).
//!
//! The engine/session code needs the external `xla` crate and is gated
//! behind the `pjrt` feature; [`Mode`] is plain metadata shared with the
//! (always-built) compression/coordinator method plumbing, so it lives
//! here unconditionally.

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(feature = "pjrt")]
mod session;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
#[cfg(feature = "pjrt")]
pub use session::{ArgBank, TranslateSession};

/// Which compiled model variant to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// `translate_dense.hlo.txt`: each compressed linear is a `[K x N]`
    /// argument (FP32 reference and quantization-only baseline).
    Dense,
    /// `translate_svd.hlo.txt`: each compressed linear is a rank-padded
    /// `[K x r_max]`, `[r_max x N]` factor pair.
    Svd,
}

impl Mode {
    pub fn key(self) -> &'static str {
        match self {
            Mode::Dense => "dense",
            Mode::Svd => "svd",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_keys() {
        assert_eq!(Mode::Dense.key(), "dense");
        assert_eq!(Mode::Svd.key(), "svd");
    }
}
