//! Hardware design-space exploration without the model in the loop —
//! the Fig. 10 study as an interactive example.
//!
//! ```bash
//! cargo run --release --example hw_explore [-- <rank>]
//! ```
//!
//! Sweeps the MatMul engine space (Baseline / Single SVD / Cascade SVD)
//! on the paper's 512x512x512 W4A8 workload under ZCU111 resource
//! constraints, prints the latency-vs-bandwidth Pareto fronts, and
//! cross-checks selected analytical design points against the
//! cycle-level dataflow simulator.

use anyhow::Result;
use itera_llm::coordinator::figures;
use itera_llm::dse::{best_design_for_layer, sweep_engines};
use itera_llm::hw::{sim, EngineKind, Platform, Workload};

fn main() -> Result<()> {
    let rank: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let platform = Platform::zcu111();
    let w = Workload::new(512, 512, 512, 4, 8);

    // ---- Fig. 10 Pareto fronts ---------------------------------------
    let t = figures::fig10(&platform);
    print!("{}", t.render());

    // ---- Design-space size + best-per-kind summary --------------------
    println!("\nrank {rank} sweep summary (ZCU111, DSP {} / BRAM18K {}):", platform.dsp, platform.bram18k);
    for kind in [EngineKind::Baseline, EngineKind::SingleSvd, EngineKind::CascadeSvd] {
        let r = if kind == EngineKind::Baseline { None } else { Some(rank) };
        let pts = sweep_engines(&w, r, &platform, &[kind]);
        let best = pts
            .iter()
            .min_by(|a, b| a.effective_latency.partial_cmp(&b.effective_latency).unwrap());
        match best {
            Some(b) => println!(
                "  {:<12} {:>6} feasible designs, best latency {:>9.0} cycles \
                 ({:.1} us) @ {:>5.0} bits/cyc, DSP {} BRAM {}",
                kind.to_string(),
                pts.len(),
                b.effective_latency,
                platform.cycles_to_us(b.effective_latency),
                b.design.bandwidth_req,
                b.design.resources.dsp,
                b.design.resources.bram18k,
            ),
            None => println!("  {:<12} no feasible design", kind.to_string()),
        }
    }

    // ---- Analytical vs simulated for the chosen best -----------------
    println!("\nanalytical vs cycle-level simulator (best baseline design):");
    if let Some(b) = best_design_for_layer(&w, None, &platform) {
        let s = sim::simulate_matmul(&w, &b.design.tile1, platform.bandwidth_bits_per_cycle);
        println!(
            "  tile {:?}: analytical {:.0} cyc, simulated {:.0} cyc ({:+.1}%), occupancy {:.1}%",
            b.design.tile1,
            b.effective_latency,
            s.cycles,
            (s.cycles / b.effective_latency - 1.0) * 100.0,
            s.occupancy * 100.0
        );
    }
    Ok(())
}
