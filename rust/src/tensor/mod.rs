//! Dense f32 matrix substrate.
//!
//! Everything the compression stack needs — matmul, transpose, slicing,
//! norms, padding — implemented directly (no BLAS in the image). The
//! matmul is the library's CPU hot path (Algorithm 1 recomputes residuals
//! every iteration) and is written cache-friendly (i-k-j loop order) so the
//! perf pass can compare against a naive baseline; see EXPERIMENTS.md §Perf.

mod matrix;

pub use matrix::Matrix;

/// Outer product of two vectors: `a (m) x b (n) -> m x n`.
pub fn outer(a: &[f32], b: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(a.len(), b.len());
    for (i, &ai) in a.iter().enumerate() {
        let row = out.row_mut(i);
        for (j, &bj) in b.iter().enumerate() {
            row[j] = ai * bj;
        }
    }
    out
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: better ILP and deterministic result.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm of a vector.
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Scale a vector in place.
pub fn scale(a: &mut [f32], s: f32) {
    for x in a {
        *x *= s;
    }
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Split the `[m x n]` row-major buffer `out` into one contiguous row
/// chunk per worker and run `kernel(i0, i1, rows)` on each from a scoped
/// thread pool — the shared scaffolding under `Matrix::matmul_par`,
/// `qkernel::QMatrix::qmatmul_par` and (as an `[n x 1]` view over the
/// output vector) `Matrix::vecmat_par`. Each element of `out` is handed
/// to exactly one kernel invocation (disjoint row ranges), so results are
/// bit-identical to running `kernel(0, m, out)` serially whenever the
/// kernel itself is row-independent.
pub(crate) fn par_row_chunks<F>(out: &mut [f32], m: usize, n: usize, workers: usize, kernel: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return; // nothing to write; chunks_mut(0) would panic below
    }
    let chunk = m.div_ceil(workers.max(1));
    std::thread::scope(|scope| {
        for (c, rows) in out.chunks_mut(chunk * n).enumerate() {
            let i0 = c * chunk;
            let i1 = i0 + rows.len() / n;
            let kernel = &kernel;
            scope.spawn(move || kernel(i0, i1, rows));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outer_shape_and_values() {
        let m = outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m.get(1, 2), 10.0);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.01).collect();
        let b: Vec<f32> = (0..103).map(|i| (103 - i) as f32 * 0.02).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }
}
