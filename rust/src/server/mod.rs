//! Network serving: a dependency-free HTTP/1.1 front end over the
//! continuous-batching serve loop.
//!
//! [`serve_http`] binds the whole stack together: an acceptor thread
//! hands connections to bounded handler threads, handlers parse JSON
//! request bodies into [`Request`]s and feed them over the same mpsc
//! channel + one-shot response channel the in-process clients use, and
//! [`serve_loop_continuous`] runs unchanged on the **caller's** thread
//! (the engine never crosses threads, so `SlotEngine` needs no `Send`).
//! Translation over HTTP is therefore bit-identical to in-process
//! serving — the network layer adds transport, not semantics.
//!
//! Routes:
//!
//! * `POST /v1/translate` — body `{"tokens": [i32...]}` plus optional
//!   `"deadline_steps"`, `"max_new_tokens"` (per-request limits) and
//!   `"stream": true` (chunked transfer encoding, one JSON line of
//!   newly decoded tokens per chunk). Unary responses carry
//!   `{"id", "tokens", "latency_s"}`.
//! * `GET /healthz` — liveness + drain state.
//! * `GET /metrics` — Prometheus text exposition of the serve loop's
//!   registry merged with the process-global one (qkernel/runtime
//!   counters). Answerable mid-drain — scraping a draining server is
//!   exactly when the numbers matter.
//! * `GET /v1/stats` — the same snapshot as JSON, plus the newest
//!   postmortem ring events (shed/expired/faulted traces).
//! * `POST /v1/shutdown` — flips the [`ShutdownSignal`]: 202, then the
//!   loop drains and [`serve_http`] returns its final [`ServeStats`].
//!
//! The fault taxonomy maps onto status codes ([`status_for`]):
//! `Overloaded` → 503, `DeadlineExceeded` → 504, `EngineFault` → 500;
//! parse/extraction failures → 400 (with the JSONPath of the offending
//! field), unknown routes → 404, oversized bodies → 413. Error bodies
//! carry the server-assigned request id
//! ([`crate::coordinator::AttributedError`]) so a client log line can
//! be matched to a server-side event.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{
    response_channel, serve_loop_continuous, Request, RequestLimits, ResponseRx, ServeConfig,
    ServeError, ServeStats, ShutdownSignal, StreamEvent, TimedRecv,
};
use crate::model::ModelDims;
use crate::obs::{Counter, Gauge, Obs, Snapshot};
use crate::runtime::SlotEngine;
use crate::util::json::Json;

pub mod http;
pub mod loadgen;

use http::{
    finish_chunks, write_chunk, write_chunked_head, write_response, write_text_response, HttpConn,
    HttpRequest, RecvError,
};

/// How often the acceptor re-checks the shutdown signal between
/// non-blocking accept attempts.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Newest postmortem ring events returned by `GET /v1/stats`.
const RING_TAIL: usize = 32;

/// Knobs for [`serve_http`] beyond the serve loop's own [`ServeConfig`].
#[derive(Clone)]
pub struct HttpConfig {
    /// The continuous serve loop's configuration (capacity, queue bound,
    /// default limits). Its `shutdown` signal is created automatically
    /// when unset — `POST /v1/shutdown` needs one to flip.
    pub serve: ServeConfig,
    /// Concurrent connections served; excess connections receive an
    /// immediate 503 and are closed (accept-side load shedding).
    pub max_connections: usize,
    /// Request bodies beyond this many bytes are rejected with 413.
    pub max_body_bytes: usize,
    /// Requests served per connection before it is closed — bounds how
    /// long one keep-alive client can pin a handler thread.
    pub keep_alive_requests: usize,
    /// Socket read timeout: the granularity at which idle handler
    /// threads notice a drain.
    pub read_timeout: Duration,
    /// Socket write timeout: bounds how long a stalled reader (a client
    /// that stops draining its socket mid-response) can pin a handler
    /// thread. A timed-out write surfaces as an `io::Error`, the
    /// handler returns, and dropping the response receiver cancels any
    /// in-flight request server-side — a slow reader costs one clean
    /// disconnect, never a wedged handler.
    pub write_timeout: Duration,
    /// Upper bound a handler waits for the serve loop's outcome before
    /// answering 500 and cancelling the request (dropping the response
    /// receiver retires the slot server-side).
    pub response_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            serve: ServeConfig::default(),
            max_connections: 256,
            max_body_bytes: 1 << 20,
            keep_alive_requests: 1024,
            read_timeout: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
            response_timeout: Duration::from_secs(30),
        }
    }
}

impl HttpConfig {
    pub fn new(serve: ServeConfig) -> HttpConfig {
        HttpConfig { serve, ..HttpConfig::default() }
    }
}

/// The HTTP status each typed serve error maps to.
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::Overloaded => 503,
        ServeError::DeadlineExceeded => 504,
        ServeError::EngineFault(_) | ServeError::Cancelled => 500,
    }
}

/// State shared by the acceptor and every handler thread.
struct Ctx {
    cfg: HttpConfig,
    shutdown: ShutdownSignal,
    /// Server-assigned request ids ([`AttributedError`] attribution).
    next_id: AtomicU64,
    /// Live handler threads (the `max_connections` bound).
    active: AtomicUsize,
    http: HttpMetrics,
}

impl Ctx {
    fn obs(&self) -> &Obs {
        &self.cfg.serve.obs
    }

    /// Count one answered request under `http_requests_total{route,status}`.
    fn note_http(&self, route: &'static str, status: u16) {
        let status = status.to_string();
        let labels = [("route", route), ("status", status.as_str())];
        self.obs().registry().counter_with("http_requests_total", &labels).inc();
    }

    /// What `/metrics` and `/v1/stats` render: the serve loop's registry
    /// merged over the process-global one (qkernel/runtime counters), so
    /// one scrape sees the whole stack.
    fn merged_snapshot(&self) -> Snapshot {
        Obs::global().registry().snapshot().merged(self.obs().registry().snapshot())
    }
}

/// Transport-level registry handles for the HTTP front end.
struct HttpMetrics {
    connections: Arc<Gauge>,
    bytes_read: Arc<Counter>,
    bytes_written: Arc<Counter>,
}

impl HttpMetrics {
    fn new(obs: &Obs) -> HttpMetrics {
        let reg = obs.registry();
        HttpMetrics {
            connections: reg.gauge("http_connections"),
            bytes_read: reg.counter("http_bytes_read_total"),
            bytes_written: reg.counter("http_bytes_written_total"),
        }
    }
}

/// Byte-counting wrapper around an accepted socket: every read and
/// write lands in `http_bytes_read_total` / `http_bytes_written_total`.
struct CountingStream<S> {
    inner: S,
    n_read: Arc<Counter>,
    n_written: Arc<Counter>,
}

impl<S: Read> Read for CountingStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.n_read.add(n as u64);
        Ok(n)
    }
}

impl<S: Write> Write for CountingStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.n_written.add(n as u64);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// The server side of one connection: an [`HttpConn`] over the counted
/// socket.
type ServerConn = HttpConn<CountingStream<TcpStream>>;

/// Serve HTTP requests over `listener` until a graceful drain
/// (`POST /v1/shutdown`, or the config's own [`ShutdownSignal`] flipped
/// externally), then return the serve loop's final [`ServeStats`]. The
/// serve loop runs on the calling thread; the listener is consumed by
/// the acceptor thread. Bind to port 0 for an ephemeral port and read
/// it back with `listener.local_addr()` before calling.
pub fn serve_http<E: SlotEngine>(
    engine: &E,
    listener: TcpListener,
    dims: &ModelDims,
    mut cfg: HttpConfig,
) -> Result<ServeStats> {
    let shutdown = match &cfg.serve.shutdown {
        Some(s) => s.clone(),
        None => {
            let s = ShutdownSignal::new();
            cfg.serve.shutdown = Some(s.clone());
            s
        }
    };
    listener.set_nonblocking(true)?;
    let (tx, rx) = mpsc::channel::<Request>();
    let http = HttpMetrics::new(&cfg.serve.obs);
    let ctx = Arc::new(Ctx {
        cfg: cfg.clone(),
        shutdown,
        next_id: AtomicU64::new(1),
        active: AtomicUsize::new(0),
        http,
    });
    let acceptor = {
        let ctx = ctx.clone();
        std::thread::spawn(move || accept_loop(listener, tx, ctx))
    };
    let stats = serve_loop_continuous(engine, &rx, dims, usize::MAX, &cfg.serve)?;
    acceptor.join().map_err(|_| anyhow::anyhow!("acceptor thread panicked"))?;
    // Every outcome was already delivered by the serve loop; give the
    // remaining handlers a moment to flush their final bytes.
    let t0 = Instant::now();
    while ctx.active.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(2) {
        std::thread::sleep(Duration::from_millis(2));
    }
    Ok(stats)
}

/// Decrements the live-connection count however the handler exits
/// (including panics — the bound must never leak).
struct ConnGuard(Arc<Ctx>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        let before = self.0.active.fetch_sub(1, Ordering::SeqCst);
        self.0.http.connections.set(before.saturating_sub(1) as f64);
    }
}

fn accept_loop(listener: TcpListener, tx: mpsc::Sender<Request>, ctx: Arc<Ctx>) {
    loop {
        if ctx.shutdown.is_draining() {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                if ctx.active.load(Ordering::SeqCst) >= ctx.cfg.max_connections {
                    // Accept-side shedding: answer before the handler
                    // pool, so overload never queues unbounded threads.
                    let body = error_json("overloaded", "connection limit reached");
                    let _ = write_response(&mut stream, 503, &body, true);
                    ctx.note_http("accept", 503);
                    continue;
                }
                let before = ctx.active.fetch_add(1, Ordering::SeqCst);
                ctx.http.connections.set((before + 1) as f64);
                let tx = tx.clone();
                let ctx = ctx.clone();
                std::thread::spawn(move || {
                    let guard = ConnGuard(ctx);
                    handle_connection(stream, tx, &guard.0);
                });
            }
            // WouldBlock (no pending connection) and transient accept
            // errors both back off to the next poll.
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_connection(stream: TcpStream, tx: mpsc::Sender<Request>, ctx: &Ctx) {
    let _ = stream.set_read_timeout(Some(ctx.cfg.read_timeout));
    // Without a write timeout a stalled reader wedges this handler
    // forever once the socket's send buffer fills; with one, the write
    // errors out, `route` reports the connection unusable, and the
    // request (if any) is cancelled by dropping its response receiver.
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let mut conn = HttpConn::new(CountingStream {
        inner: stream,
        n_read: ctx.http.bytes_read.clone(),
        n_written: ctx.http.bytes_written.clone(),
    });
    let mut served = 0usize;
    while served < ctx.cfg.keep_alive_requests {
        let req = match conn.read_request(ctx.cfg.max_body_bytes) {
            Ok(req) => req,
            Err(RecvError::Idle) => {
                if ctx.shutdown.is_draining() {
                    return;
                }
                continue; // keep-alive idle; doesn't consume the budget
            }
            Err(RecvError::Closed) | Err(RecvError::Io(_)) => return,
            Err(RecvError::TooLarge) => {
                let body =
                    error_json("payload_too_large", "request body exceeds the configured cap");
                let _ = write_response(conn.get_mut(), 413, &body, true);
                ctx.note_http("other", 413);
                return;
            }
            Err(RecvError::Bad(msg)) => {
                let body = error_json("bad_request", &msg);
                let _ = write_response(conn.get_mut(), 400, &body, true);
                ctx.note_http("other", 400);
                return;
            }
        };
        served += 1;
        let close = req.wants_close() || served == ctx.cfg.keep_alive_requests;
        if !route(&mut conn, &req, close, &tx, ctx) || close {
            return;
        }
    }
}

/// The `route` label a target is counted under — known routes keep
/// their path, everything else collapses into `other` so a URL scan
/// cannot explode the metric's cardinality.
fn route_key(target: &str) -> &'static str {
    match target {
        "/healthz" => "/healthz",
        "/metrics" => "/metrics",
        "/v1/stats" => "/v1/stats",
        "/v1/shutdown" => "/v1/shutdown",
        "/v1/translate" => "/v1/translate",
        _ => "other",
    }
}

/// Dispatch one request; `false` means the connection is no longer
/// usable (write failure or a mid-stream error).
fn route(
    conn: &mut ServerConn,
    req: &HttpRequest,
    close: bool,
    tx: &mpsc::Sender<Request>,
    ctx: &Ctx,
) -> bool {
    match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => {
            let body = Json::obj(vec![
                ("status", Json::Str("ok".to_string())),
                ("draining", Json::Bool(ctx.shutdown.is_draining())),
            ]);
            ctx.note_http("/healthz", 200);
            write_response(conn.get_mut(), 200, &body, close).is_ok()
        }
        // Telemetry routes stay answerable mid-drain: scraping a
        // draining server is exactly when the numbers matter.
        ("GET", "/metrics") => {
            ctx.note_http("/metrics", 200);
            let text = ctx.merged_snapshot().to_prometheus();
            write_text_response(conn.get_mut(), 200, &text, close).is_ok()
        }
        ("GET", "/v1/stats") => {
            ctx.note_http("/v1/stats", 200);
            let body = Json::obj(vec![
                ("metrics", ctx.merged_snapshot().to_json()),
                ("events", ctx.obs().ring().to_json(RING_TAIL)),
            ]);
            write_response(conn.get_mut(), 200, &body, close).is_ok()
        }
        ("POST", "/v1/shutdown") => {
            ctx.shutdown.drain();
            let body = Json::obj(vec![("draining", Json::Bool(true))]);
            ctx.note_http("/v1/shutdown", 202);
            write_response(conn.get_mut(), 202, &body, close).is_ok()
        }
        ("POST", "/v1/translate") => translate(conn, req, close, tx, ctx),
        (_, "/healthz" | "/metrics" | "/v1/stats" | "/v1/shutdown" | "/v1/translate") => {
            let msg = format!("{} not supported on {}", req.method, req.target);
            let body = error_json("method_not_allowed", &msg);
            ctx.note_http(route_key(&req.target), 405);
            write_response(conn.get_mut(), 405, &body, close).is_ok()
        }
        _ => {
            let body = error_json("not_found", &format!("no route for {}", req.target));
            ctx.note_http("other", 404);
            write_response(conn.get_mut(), 404, &body, close).is_ok()
        }
    }
}

fn translate(
    conn: &mut ServerConn,
    req: &HttpRequest,
    close: bool,
    tx: &mpsc::Sender<Request>,
    ctx: &Ctx,
) -> bool {
    let id = ctx.next_id.fetch_add(1, Ordering::SeqCst);
    let (tokens, limits, stream) = match parse_translate(&req.body) {
        Ok(parts) => parts,
        Err(msg) => {
            let body = error_body(id, "bad_request", &msg);
            ctx.note_http("/v1/translate", 400);
            return write_response(conn.get_mut(), 400, &body, close).is_ok();
        }
    };
    if ctx.shutdown.is_draining() {
        let e = ServeError::Overloaded;
        let body = error_body(id, e.key(), &e.clone().attributed(id).to_string());
        ctx.note_http("/v1/translate", 503);
        return write_response(conn.get_mut(), 503, &body, close).is_ok();
    }
    let (rtx, rrx) = response_channel();
    let mut r = Request::new(tokens, rtx).with_limits(limits);
    if stream {
        r = r.with_stream();
    }
    if tx.send(r).is_err() {
        // The serve loop is gone (drained): nothing will ever answer.
        let body = error_body(id, ServeError::Overloaded.key(), "server is draining");
        ctx.note_http("/v1/translate", 503);
        return write_response(conn.get_mut(), 503, &body, close).is_ok();
    }
    if stream {
        stream_response(conn, id, &rrx, ctx)
    } else {
        unary_response(conn, id, close, &rrx, ctx)
    }
}

/// Parse a translate request body into (tokens, limits, stream).
fn parse_translate(body: &[u8]) -> Result<(Vec<i32>, RequestLimits, bool), String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let j = Json::parse(text).map_err(|e| e.to_string())?;
    let x = j.extract();
    let tokens = x
        .field("tokens")
        .and_then(|t| t.i32s())
        .map_err(|e| e.to_string())?;
    if tokens.is_empty() {
        return Err("at $.tokens: must be non-empty".to_string());
    }
    let opt_usize = |key: &str| -> Result<Option<usize>, String> {
        match x.opt(key).map_err(|e| e.to_string())? {
            Some(v) => Ok(Some(v.usize().map_err(|e| e.to_string())?)),
            None => Ok(None),
        }
    };
    let mut limits = RequestLimits::none();
    if let Some(d) = opt_usize("deadline_steps")? {
        limits = limits.with_deadline(d);
    }
    if let Some(m) = opt_usize("max_new_tokens")? {
        limits = limits.with_max_new_tokens(m);
    }
    let stream = match x.opt("stream").map_err(|e| e.to_string())? {
        Some(v) => v.bool().map_err(|e| e.to_string())?,
        None => false,
    };
    Ok((tokens, limits, stream))
}

fn unary_response(
    conn: &mut ServerConn,
    id: u64,
    close: bool,
    rrx: &ResponseRx,
    ctx: &Ctx,
) -> bool {
    match rrx.recv_timeout(ctx.cfg.response_timeout) {
        TimedRecv::Ready(Ok(resp)) => {
            let body = Json::obj(vec![
                ("id", num_u64(id)),
                ("tokens", tokens_json(&resp.tokens)),
                ("latency_s", Json::Num(resp.latency_s)),
            ]);
            ctx.note_http("/v1/translate", 200);
            write_response(conn.get_mut(), 200, &body, close).is_ok()
        }
        TimedRecv::Ready(Err(e)) => {
            let body = error_body(id, e.key(), &e.clone().attributed(id).to_string());
            ctx.note_http("/v1/translate", status_for(&e));
            write_response(conn.get_mut(), status_for(&e), &body, close).is_ok()
        }
        TimedRecv::SenderGone => {
            let body = error_body(id, "overloaded", "server dropped the request during drain");
            ctx.note_http("/v1/translate", 503);
            write_response(conn.get_mut(), 503, &body, close).is_ok()
        }
        TimedRecv::TimedOut => {
            // The caller drops `rrx` right after us, which cancels the
            // server-side slot instead of decoding for nobody.
            let body = error_body(id, "engine_fault", "response timed out; request cancelled");
            ctx.note_http("/v1/translate", 500);
            write_response(conn.get_mut(), 500, &body, close).is_ok()
        }
    }
}

/// Chunked streaming response: one JSON line per progress event, a
/// terminal line carrying the tail tokens + latency (success) or the
/// typed error, then the chunked-body terminator.
fn stream_response(conn: &mut ServerConn, id: u64, rrx: &ResponseRx, ctx: &Ctx) -> bool {
    // Streaming responses count at head-write time; outcome errors still
    // travel inside the 200 chunked body (terminal JSON line).
    ctx.note_http("/v1/translate", 200);
    let w = conn.get_mut();
    if write_chunked_head(w, 200).is_err() {
        return false;
    }
    let deadline = Instant::now() + ctx.cfg.response_timeout;
    let mut streamed = 0usize;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        let event = if left.is_zero() { StreamEvent::TimedOut } else { rrx.recv_progress(left) };
        match event {
            StreamEvent::Tokens(ts) => {
                streamed += ts.len();
                let line = Json::obj(vec![("id", num_u64(id)), ("tokens", tokens_json(&ts))]);
                if write_chunk(w, line_bytes(&line).as_slice()).is_err() {
                    return false;
                }
            }
            StreamEvent::Done(Ok(resp)) => {
                // Progress pushes covered `streamed` tokens; the rest
                // (the final decode step's output) rides the terminal
                // line, so the concatenation is the full response.
                let tail = &resp.tokens[streamed.min(resp.tokens.len())..];
                let line = Json::obj(vec![
                    ("id", num_u64(id)),
                    ("done", Json::Bool(true)),
                    ("tokens", tokens_json(tail)),
                    ("latency_s", Json::Num(resp.latency_s)),
                ]);
                let ok = write_chunk(w, line_bytes(&line).as_slice()).is_ok();
                return finish_chunks(w).is_ok() && ok;
            }
            StreamEvent::Done(Err(e)) => {
                let line = error_body(id, e.key(), &e.clone().attributed(id).to_string());
                let ok = write_chunk(w, line_bytes(&line).as_slice()).is_ok();
                return finish_chunks(w).is_ok() && ok;
            }
            StreamEvent::SenderGone => {
                let line = error_body(id, "overloaded", "server dropped the request during drain");
                let _ = write_chunk(w, line_bytes(&line).as_slice());
                let _ = finish_chunks(w);
                return false;
            }
            StreamEvent::TimedOut => {
                let line = error_body(id, "engine_fault", "response timed out; request cancelled");
                let _ = write_chunk(w, line_bytes(&line).as_slice());
                let _ = finish_chunks(w);
                return false;
            }
        }
    }
}

fn line_bytes(j: &Json) -> Vec<u8> {
    let mut s = j.to_string();
    s.push('\n');
    s.into_bytes()
}

fn num_u64(n: u64) -> Json {
    Json::Num(n as f64)
}

fn tokens_json(ts: &[i32]) -> Json {
    Json::Arr(ts.iter().map(|&t| Json::Num(f64::from(t))).collect())
}

fn error_json(key: &str, message: &str) -> Json {
    Json::obj(vec![
        ("error", Json::Str(key.to_string())),
        ("message", Json::Str(message.to_string())),
    ])
}

/// Error body with the server-assigned request id (the
/// [`crate::coordinator::AttributedError`] attribution on the wire).
fn error_body(id: u64, key: &str, message: &str) -> Json {
    Json::obj(vec![
        ("id", num_u64(id)),
        ("error", Json::Str(key.to_string())),
        ("message", Json::Str(message.to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    use http::write_request;

    /// Echo slot engine (mirrors the serve-loop unit tests): completes
    /// after one step, output echoes the framed row.
    struct EchoSlots {
        seq: usize,
    }

    struct EchoSlot {
        row: Vec<i32>,
        steps: usize,
    }

    impl SlotEngine for EchoSlots {
        type Slot = EchoSlot;
        fn slot_seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&self, src_row: &[i32]) -> Result<EchoSlot> {
            Ok(EchoSlot { row: src_row.to_vec(), steps: 0 })
        }
        fn step(&self, slots: &mut [&mut EchoSlot]) -> Result<()> {
            for s in slots.iter_mut() {
                s.steps += 1;
            }
            Ok(())
        }
        fn slot_complete(&self, slot: &EchoSlot) -> bool {
            slot.steps >= 1
        }
        fn slot_output(&self, slot: &EchoSlot) -> Vec<i32> {
            slot.row.clone()
        }
    }

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            n_enc: 1,
            n_dec: 1,
            seq_len: 6,
            eval_batch: 4,
            pad_id: 0,
            bos_id: 1,
            eos_id: 2,
        }
    }

    #[test]
    fn http_smoke_translate_health_errors_shutdown() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let engine = EchoSlots { seq: 6 };
            serve_http(&engine, listener, &dims(), HttpConfig::new(ServeConfig::new(2))).unwrap()
        });

        let stream = TcpStream::connect(addr).unwrap();
        let mut conn = HttpConn::new(stream);

        // Health first.
        write_request(conn.get_mut(), "GET", "/healthz", None).unwrap();
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.json().unwrap().get("status").as_str(), Some("ok"));

        // A translate round-trip on the same keep-alive connection.
        let body = Json::obj(vec![("tokens", Json::arr_f64(&[1.0, 9.0, 2.0]))]);
        write_request(conn.get_mut(), "POST", "/v1/translate", Some(&body)).unwrap();
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        let j = resp.json().unwrap();
        assert_eq!(j.get("tokens").as_arr().unwrap().len(), 1, "echo de-frames to [9]");
        assert_eq!(j.get("tokens").idx(0).as_f64(), Some(9.0));
        assert!(j.get("id").as_f64().is_some());

        // Typed 400 with the offending JSONPath.
        let bad = Json::obj(vec![("tokens", Json::Str("nope".to_string()))]);
        write_request(conn.get_mut(), "POST", "/v1/translate", Some(&bad)).unwrap();
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 400);
        let msg = resp.json().unwrap().get("message").as_str().unwrap_or("").to_string();
        assert!(msg.contains("$.tokens"), "400 names the bad field: {msg}");

        // 404 and 405.
        write_request(conn.get_mut(), "GET", "/nope", None).unwrap();
        assert_eq!(conn.read_response().unwrap().status, 404);
        write_request(conn.get_mut(), "GET", "/v1/translate", None).unwrap();
        assert_eq!(conn.read_response().unwrap().status, 405);

        // Live telemetry: /metrics is Prometheus text the crate's own
        // parser reads back, and it already accounts this connection's
        // requests; /v1/stats carries the same snapshot as JSON.
        write_request(conn.get_mut(), "GET", "/metrics", None).unwrap();
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.header("content-type").unwrap_or("").starts_with("text/plain"));
        let text = String::from_utf8(resp.body).unwrap();
        let parsed = crate::obs::parse_text(&text);
        assert_eq!(
            parsed.get(&crate::obs::key(
                "http_requests_total",
                &[("route", "/v1/translate"), ("status", "200")]
            )),
            Some(&1.0),
            "{text}"
        );
        assert_eq!(parsed.get("serve_received_total"), Some(&1.0));
        assert!(parsed.get("http_bytes_read_total").copied().unwrap_or(0.0) > 0.0);

        write_request(conn.get_mut(), "GET", "/v1/stats", None).unwrap();
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        let stats_json = resp.json().unwrap();
        let metrics = stats_json.get("metrics");
        assert_eq!(
            metrics.get("counters").get("serve_received_total").as_f64(),
            Some(1.0),
            "/v1/stats mirrors the registry"
        );

        // Graceful shutdown: 202, then the server thread joins with
        // balanced books.
        write_request(conn.get_mut(), "POST", "/v1/shutdown", None).unwrap();
        assert_eq!(conn.read_response().unwrap().status, 202);
        let stats = server.join().expect("server thread");
        assert_eq!(stats.served, 1);
        assert!(stats.is_balanced(), "{stats:?}");
    }

    #[test]
    fn parse_translate_covers_limits_and_stream() {
        let body = br#"{"tokens": [1, 5, 2], "deadline_steps": 9, "stream": true}"#;
        let (tokens, limits, stream) = parse_translate(body).unwrap();
        assert_eq!(tokens, vec![1, 5, 2]);
        assert_eq!(limits.deadline_steps, Some(9));
        assert_eq!(limits.max_new_tokens, None);
        assert!(stream);

        let (_, limits, stream) = parse_translate(br#"{"tokens": [3]}"#).unwrap();
        assert_eq!(limits, RequestLimits::none());
        assert!(!stream);

        assert!(parse_translate(b"{").unwrap_err().contains("parse error"));
        assert!(parse_translate(br#"{"tokens": []}"#).unwrap_err().contains("non-empty"));
        let e = parse_translate(br#"{"tokens": [1.5]}"#).unwrap_err();
        assert!(e.contains("$.tokens[0]"), "{e}");
        let e = parse_translate(br#"{"tokens": [1], "deadline_steps": -4}"#).unwrap_err();
        assert!(e.contains("non-negative"), "{e}");
    }
}
