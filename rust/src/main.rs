//! `itera` — CLI entry point for the ITERA-LLM co-design framework.
//!
//! The full CLI drives the PJRT runtime and therefore needs the `pjrt`
//! feature (which in turn needs the external `xla` crate). The default
//! build still produces the binary so `cargo build --release` stays green,
//! but it only explains how to get the full tool.

#[cfg(feature = "pjrt")]
fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = itera_llm::cli::main_with_args(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "itera: built without the `pjrt` feature.\n\
         The compression engine, SRA, hardware models and DSE are available \
         as a library;\nthe CLI (figures, serving, BLEU evaluation) needs \
         `cargo build --features pjrt`\nwith the `xla` crate vendored. See \
         rust/Cargo.toml."
    );
    std::process::exit(2);
}
