//! Criterion-style bench harness (the image vendors no criterion crate).
//!
//! `cargo bench` runs the `[[bench]]` targets with `harness = false`; each
//! target builds a [`Bench`] suite, registers closures, and the harness
//! does warmup + timed sampling and prints mean/median/stddev/throughput.
//! Honors the standard `cargo bench <filter>` argument.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    /// Items processed per iteration (set by [`Bench::bench_throughput`]);
    /// serialized as `items_per_s` in the JSON trajectory.
    pub items: Option<u64>,
    /// Non-timed scalar metric (set by [`Bench::gauge`]); entries carrying
    /// a value serialize as `{value: v}` instead of timing fields — used
    /// for deterministic accounting like packed weight bytes.
    pub value: Option<f64>,
}

/// Bench suite runner.
pub struct Bench {
    filter: Option<String>,
    /// Active group label: while set, the `cargo bench <filter>` match
    /// also runs against this label, so a whole block of related lanes
    /// can be selected by its group name even when the individual lane
    /// names don't contain it (e.g. `cargo bench --bench hot_paths
    /// batcher` for the `runtime/native_serve_*` lanes).
    group: Option<String>,
    warmup_iters: usize,
    min_samples: usize,
    max_samples: usize,
    target_time_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // `cargo bench foo` passes "foo" plus `--bench`; take the first
        // non-flag arg as a substring filter.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench {
            filter,
            group: None,
            warmup_iters: 2,
            min_samples: 5,
            max_samples: 30,
            target_time_s: 2.0,
            results: Vec::new(),
        }
    }

    /// Quick profile for smoke runs (fewer samples).
    pub fn quick(mut self) -> Bench {
        self.warmup_iters = 1;
        self.min_samples = 3;
        self.max_samples = 8;
        self.target_time_s = 0.5;
        self
    }

    /// Minimal profile for expensive end-to-end benches (figure
    /// regenerations run seconds-to-minutes per sample).
    pub fn minimal(mut self) -> Bench {
        self.warmup_iters = 0;
        self.min_samples = 2;
        self.max_samples = 2;
        self.target_time_s = 0.0;
        self
    }

    /// Whether `name` passes the active `cargo bench <filter>` (suites use
    /// this to skip expensive setup whose benches are filtered out). The
    /// active [`group`](Self::set_group) label matches too.
    pub fn enabled(&self, name: &str) -> bool {
        let Some(f) = self.filter.as_deref() else { return true };
        name.contains(f) || self.group.as_deref().map(|g| g.contains(f)).unwrap_or(false)
    }

    /// Enter (`Some`) or leave (`None`) a named group of lanes: while a
    /// group is active, `enabled` also matches the filter against the
    /// group label, so `cargo bench <group>` selects every lane the
    /// block registers regardless of lane naming.
    pub fn set_group(&mut self, group: Option<&str>) {
        self.group = group.map(str::to_string);
    }

    /// Register and run one benchmark.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        let t_suite = Instant::now();
        while s.count() < self.min_samples
            || (s.count() < self.max_samples
                && t_suite.elapsed().as_secs_f64() < self.target_time_s)
        {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            samples: s.count(),
            mean_s: s.mean(),
            median_s: s.median(),
            stddev_s: s.stddev(),
            min_s: s.min(),
            items: None,
            value: None,
        };
        println!(
            "{:<44} {:>10.4} ms/iter (median {:.4}, sd {:.4}, n={})",
            r.name,
            r.mean_s * 1e3,
            r.median_s * 1e3,
            r.stddev_s * 1e3,
            r.samples
        );
        self.results.push(r);
    }

    /// Benchmark with a throughput annotation (items/sec at the mean,
    /// also merged into the JSON trajectory as `items_per_s`).
    pub fn bench_throughput(&mut self, name: &str, items: u64, f: impl FnMut()) {
        let before = self.results.len();
        self.bench(name, f);
        if self.results.len() > before {
            let r = &mut self.results[before];
            r.items = Some(items);
            println!(
                "{:<44} {:>10.1} items/s",
                format!("  -> {}", r.name),
                items as f64 / r.mean_s
            );
        }
    }

    /// Record a non-timed scalar metric (bytes, ratios, counts) into the
    /// trajectory — deterministic accounting entries that live alongside
    /// the timings (e.g. `qkernel/packed_bytes_*`). Honors the active
    /// filter like any bench.
    pub fn gauge(&mut self, name: &str, value: f64) {
        if !self.enabled(name) {
            return;
        }
        println!("{:<44} {:>14.1} (gauge)", name, value);
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: 0,
            mean_s: 0.0,
            median_s: 0.0,
            stddev_s: 0.0,
            min_s: 0.0,
            items: None,
            value: Some(value),
        });
    }

    /// Export a telemetry [`Snapshot`](crate::obs::Snapshot)'s counters
    /// and gauges as gauge entries named `{prefix}/{metric key}`, so the
    /// registry state a bench lane accumulated lands in the JSON
    /// trajectory next to its timings. Honors the active filter like any
    /// other entry.
    pub fn export_snapshot(&mut self, prefix: &str, snap: &crate::obs::Snapshot) {
        for (k, v) in &snap.counters {
            self.gauge(&format!("{prefix}/{k}"), *v as f64);
        }
        for (k, v) in &snap.gauges {
            self.gauge(&format!("{prefix}/{k}"), *v);
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write the recorded results as machine-readable JSON (the
    /// `BENCH_<suite>.json` trajectory files; see EXPERIMENTS.md §Perf).
    ///
    /// Merges into an existing trajectory: only the entries this run
    /// actually executed are updated, so a filtered run — or a build
    /// missing optional benches (no `pjrt`, no artifacts) — refreshes its
    /// own entries without clobbering the rest.
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        use crate::util::json::Json;
        let path = path.as_ref();
        let mut benches = std::collections::BTreeMap::new();
        match std::fs::read_to_string(path) {
            Ok(text) => match Json::parse(&text) {
                Ok(j) => {
                    if let Some(m) = j.get("benches").as_obj() {
                        benches = m.clone();
                    }
                }
                Err(e) => {
                    // Never silently drop history: preserve the unreadable
                    // file next to the new one and say so.
                    let backup = path.with_extension("json.corrupt");
                    let moved = std::fs::rename(path, &backup).is_ok();
                    eprintln!(
                        "[bench] existing trajectory {path:?} is unparseable ({e}); {}",
                        if moved {
                            format!("preserved as {backup:?}")
                        } else {
                            "could not preserve it".to_string()
                        }
                    );
                }
            },
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            // Unreadable-but-present (permissions, I/O error): abort rather
            // than overwrite history we could not merge with.
            Err(e) => return Err(e),
        }
        for r in &self.results {
            if let Some(v) = r.value {
                benches.insert(r.name.clone(), Json::obj(vec![("value", Json::Num(v))]));
                continue;
            }
            let mut fields = vec![
                ("mean_s", Json::Num(r.mean_s)),
                ("median_s", Json::Num(r.median_s)),
                ("stddev_s", Json::Num(r.stddev_s)),
                ("min_s", Json::Num(r.min_s)),
                ("samples", Json::Num(r.samples as f64)),
            ];
            if let Some(items) = r.items {
                fields.push(("items_per_s", Json::Num(items as f64 / r.mean_s)));
            }
            benches.insert(r.name.clone(), Json::obj(fields));
        }
        let doc = Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("benches", Json::Obj(benches)),
        ]);
        // Write-then-rename so an interrupted run can't truncate the file.
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, doc.to_string_pretty())?;
        std::fs::rename(&tmp, path)
    }

    pub fn finish(&self) {
        println!("\n{} benchmarks run.", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench::new().quick();
        b.filter = None;
        let mut count = 0u64;
        b.bench("noop", || {
            count += 1;
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].samples >= 3);
        assert!(count >= 4); // warmup + samples
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench::new().quick();
        b.filter = Some("match-me".to_string());
        b.bench("other", || {});
        assert!(b.results().is_empty());
        b.bench("match-me-too", || {});
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn group_label_matches_filter() {
        let mut b = Bench::new().quick();
        b.filter = Some("batcher".to_string());
        b.bench("runtime/native_serve_static", || {});
        assert!(b.results().is_empty(), "lane name alone does not match");
        b.set_group(Some("batcher"));
        assert!(b.enabled("runtime/native_serve_static"), "group label matches the filter");
        b.bench("runtime/native_serve_static", || {});
        assert_eq!(b.results().len(), 1);
        b.set_group(None);
        b.bench("runtime/native_serve_continuous", || {});
        assert_eq!(b.results().len(), 1, "leaving the group restores name-only matching");
        // No filter: everything runs, group or not.
        b.filter = None;
        b.bench("anything", || {});
        assert_eq!(b.results().len(), 2);
    }

    #[test]
    fn throughput_lands_in_json() {
        use crate::util::json::Json;
        let path = std::env::temp_dir().join("itera_benchkit_tput_test.json");
        std::fs::remove_file(&path).ok();
        let mut b = Bench::new().quick();
        b.filter = None;
        b.bench_throughput("suite/tokens", 1000, || {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        b.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let e = j.get("benches").get("suite/tokens");
        let ips = e.get("items_per_s").as_f64().expect("items_per_s present");
        let mean = e.get("mean_s").as_f64().unwrap();
        assert!((ips - 1000.0 / mean).abs() / ips < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gauges_land_in_json_and_honor_filter() {
        use crate::util::json::Json;
        let path = std::env::temp_dir().join("itera_benchkit_gauge_test.json");
        std::fs::remove_file(&path).ok();
        let mut b = Bench::new().quick();
        b.filter = Some("keep".to_string());
        b.gauge("suite/keep_bytes", 133120.0);
        b.gauge("suite/dropped", 1.0);
        assert_eq!(b.results().len(), 1, "filter must apply to gauges");
        b.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let v = j.get("benches").get("suite/keep_bytes").get("value");
        assert_eq!(v.as_f64(), Some(133120.0));
        assert!(j.get("benches").get("suite/dropped").get("value").as_f64().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn snapshot_export_lands_as_gauges() {
        let _gate = crate::obs::test_gate().read().unwrap_or_else(|e| e.into_inner());
        let obs = crate::obs::Obs::fresh();
        obs.registry().counter("demo_total").add(7);
        obs.registry().gauge("demo_depth").set(3.0);
        let mut b = Bench::new().quick();
        b.filter = None;
        b.export_snapshot("suite", &obs.registry().snapshot());
        let by_name: std::collections::BTreeMap<_, _> =
            b.results().iter().map(|r| (r.name.as_str(), r.value)).collect();
        assert_eq!(by_name.get("suite/demo_total"), Some(&Some(7.0)));
        assert_eq!(by_name.get("suite/demo_depth"), Some(&Some(3.0)));
    }

    #[test]
    fn json_trajectory_roundtrips_and_merges() {
        use crate::util::json::Json;
        let path = std::env::temp_dir().join("itera_benchkit_test.json");
        std::fs::remove_file(&path).ok();
        let mut b = Bench::new().quick();
        b.filter = None;
        b.bench("suite/alpha", || {});
        b.bench("suite/beta", || {});
        b.write_json(&path).unwrap();
        // A later partial run must update its own entries and keep the rest.
        let mut b2 = Bench::new().quick();
        b2.filter = None;
        b2.bench("suite/gamma", || {});
        b2.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let benches = j.get("benches");
        assert!(benches.get("suite/alpha").get("mean_s").as_f64().is_some());
        assert!(benches.get("suite/beta").get("samples").as_usize().unwrap() >= 3);
        assert!(benches.get("suite/gamma").get("mean_s").as_f64().is_some());
        std::fs::remove_file(&path).ok();
    }
}
