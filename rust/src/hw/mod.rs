//! FPGA hardware modelling (§V–§VI).
//!
//! The paper evaluates its MatMul engines *analytically* — rate/workload
//! performance models (Eq. 12–15), DSP/BRAM/bandwidth resource models
//! (Eq. 16–19) — under ZCU111 constraints with Vitis-style BRAM mapping.
//! This module implements those models exactly, plus a cycle-level
//! dataflow simulator ([`sim`]) that cross-validates the analytical
//! latency and provides the per-layer occupancy of Fig. 12.

mod engines;
mod perf;
mod resources;
pub mod sim;

pub use engines::{CascadeSvdEngine, EngineDesign, EngineKind, SingleSvdEngine};
pub use perf::{bandwidth_bits_per_cycle, tile_latency_cycles, PortRates, TilePerf};
pub use resources::{bram18_units, f_packing, tile_resources, Resources};

/// A dense MatMul workload `Y[M x N] = X[M x K] * W[K x N]` with fixed-point
/// word lengths (the `WxAy` scheme).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Weight word length (bits).
    pub w_bits: u32,
    /// Activation word length (bits).
    pub a_bits: u32,
}

impl Workload {
    pub fn new(m: usize, k: usize, n: usize, w_bits: u32, a_bits: u32) -> Self {
        Workload { m, k, n, w_bits, a_bits }
    }

    /// Total MACs.
    pub fn macs(&self) -> u64 {
        (self.m as u64) * (self.k as u64) * (self.n as u64)
    }
}

/// Tile parameterization of the PE array (Fig. 5): `M_t x N_t` PEs, each a
/// vector-dot engine with `K_f` parallel multipliers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileConfig {
    pub mt: usize,
    pub nt: usize,
    pub kf: usize,
}

impl TileConfig {
    pub fn new(mt: usize, nt: usize, kf: usize) -> Self {
        assert!(mt > 0 && nt > 0 && kf > 0);
        TileConfig { mt, nt, kf }
    }

    pub fn pes(&self) -> usize {
        self.mt * self.nt
    }
}

/// Target platform resource budget. Defaults model the ZCU111 at 200 MHz
/// (§VIII-A): 4272 DSP48E2, 1080 BRAM18K, and a DDR4 interface whose
/// practical bandwidth at 200 MHz is ~`85` Gb/s ≈ 427 bits/cycle; the
/// paper's Fig. 11 (right) also evaluates a quarter-bandwidth variant.
#[derive(Debug, Clone, Copy)]
pub struct Platform {
    pub name: &'static str,
    pub dsp: usize,
    pub bram18k: usize,
    /// Off-chip bits per cycle available to the accelerator.
    pub bandwidth_bits_per_cycle: f64,
    pub clock_mhz: f64,
}

impl Platform {
    pub fn zcu111() -> Platform {
        Platform {
            name: "ZCU111",
            dsp: 4272,
            bram18k: 1080,
            bandwidth_bits_per_cycle: 427.0,
            clock_mhz: 200.0,
        }
    }

    /// Fig. 11 (right): a quarter of the original bandwidth, simulating an
    /// extreme bandwidth-limited deployment.
    pub fn zcu111_quarter_bw() -> Platform {
        let mut p = Self::zcu111();
        p.name = "ZCU111/4bw";
        p.bandwidth_bits_per_cycle /= 4.0;
        p
    }

    /// Convert cycles to microseconds at the platform clock.
    pub fn cycles_to_us(&self, cycles: f64) -> f64 {
        cycles / self.clock_mhz
    }
}

pub(crate) fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_presets() {
        let p = Platform::zcu111();
        assert_eq!(p.dsp, 4272);
        assert_eq!(p.bram18k, 1080);
        let q = Platform::zcu111_quarter_bw();
        assert!((q.bandwidth_bits_per_cycle - p.bandwidth_bits_per_cycle / 4.0).abs() < 1e-9);
        assert!((p.cycles_to_us(200.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn workload_macs() {
        assert_eq!(Workload::new(512, 512, 512, 4, 8).macs(), 512u64.pow(3));
    }
}
