//! Deterministic fault injection for the serving stack.
//!
//! [`FaultyEngine`] wraps any [`SlotEngine`] and injects failures
//! according to a seeded [`FaultPlan`]: admissions can be born poisoned
//! (admit fails or panics), live slots can fault at a scripted decode
//! step, and slots can stall — consuming steps without ever completing,
//! so only a deadline can reclaim them. Non-faulted slots delegate
//! straight to the inner engine, so their outputs stay **bit-identical**
//! to a fault-free run — exactly the invariant the chaos soak asserts.
//!
//! Determinism is the whole point: each admission's fault script is a
//! pure function of `(plan.seed, admission index)`, independent of
//! thread timing, tick interleaving, or how many random draws other
//! admissions consumed. The same seed therefore replays the same chaos,
//! and a failing soak run names a single integer to reproduce it.
//!
//! Faults are injected **before** delegating to the inner engine, which
//! keeps the wrapper re-steppable on failure — the batcher's per-slot
//! fault attribution (re-step each slot solo after a batched step
//! fails) observes the same scripted outcome every time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::runtime::SlotEngine;
use crate::util::rng::Pcg64;

/// Fault probabilities for a seeded chaos run. All rates are per
/// admission, in `[0, 1]`; an admission draws at most one fault kind
/// (checked in the order born-poisoned → stall → step-fault).
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Seed for the per-admission script derivation.
    pub seed: u64,
    /// P(admission fails outright — `admit` errors or panics).
    pub admit_fault: f64,
    /// P(the slot faults at a scripted decode step).
    pub step_fault: f64,
    /// Of the faults above, the fraction delivered as panics rather
    /// than `Err` returns (exercises the `catch_unwind` isolation path).
    pub panic_frac: f64,
    /// P(the slot stalls: steps are consumed but it never completes;
    /// only a deadline reclaims it).
    pub stall: f64,
}

impl FaultPlan {
    /// A plan that injects nothing — the wrapper becomes a transparent
    /// pass-through (useful to validate the harness itself).
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan { seed, admit_fault: 0.0, step_fault: 0.0, panic_frac: 0.0, stall: 0.0 }
    }

    /// The fault script for the `admission`-th admission (0-based).
    /// Pure in `(self.seed, admission)`: tests can predict every
    /// injected fault without running the engine.
    pub fn script(&self, admission: u64) -> FaultScript {
        let mut rng = Pcg64::new(self.seed ^ admission.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let roll = rng.next_f64();
        let panics = rng.next_f64() < self.panic_frac;
        // Disjoint probability bands: one fault kind per admission.
        if roll < self.admit_fault {
            FaultScript { born_poisoned: true, stalls: false, fault_at_step: None, panics }
        } else if roll < self.admit_fault + self.stall {
            FaultScript { born_poisoned: false, stalls: true, fault_at_step: None, panics }
        } else if roll < self.admit_fault + self.stall + self.step_fault {
            let at = rng.below(4);
            FaultScript {
                born_poisoned: false,
                stalls: false,
                fault_at_step: Some(at),
                panics,
            }
        } else {
            FaultScript::clean()
        }
    }
}

/// What happens to one admission, decided up-front.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultScript {
    /// `admit` itself fails (error or panic, per `panics`).
    pub born_poisoned: bool,
    /// The slot consumes steps but never completes.
    pub stalls: bool,
    /// The slot faults the moment it reaches this step count.
    pub fault_at_step: Option<usize>,
    /// Deliver faults as panics instead of `Err` returns.
    pub panics: bool,
}

impl FaultScript {
    pub fn clean() -> FaultScript {
        FaultScript { born_poisoned: false, stalls: false, fault_at_step: None, panics: false }
    }

    /// Will this admission ever produce a successful output?
    pub fn survives(&self) -> bool {
        !self.born_poisoned && !self.stalls && self.fault_at_step.is_none()
    }
}

enum Scripts {
    /// Derived from a seeded plan (pure per-admission function).
    Seeded(FaultPlan),
    /// Explicit per-admission list; admissions beyond it are clean.
    Explicit(Vec<FaultScript>),
}

/// A [`SlotEngine`] wrapper that injects scripted faults. `Sync` when
/// the inner engine is (the admission counter is atomic), so chaos
/// tests can serve from one thread while clients run on others.
pub struct FaultyEngine<'a, E: SlotEngine> {
    inner: &'a E,
    scripts: Scripts,
    admissions: AtomicU64,
    /// Admission order log: `injected[i]` is the script admission `i`
    /// actually received — lets tests map batcher ids to fates.
    log: Mutex<Vec<FaultScript>>,
}

impl<'a, E: SlotEngine> FaultyEngine<'a, E> {
    /// Seeded chaos mode: each admission's fate comes from
    /// [`FaultPlan::script`].
    pub fn new(inner: &'a E, plan: FaultPlan) -> FaultyEngine<'a, E> {
        FaultyEngine {
            inner,
            scripts: Scripts::Seeded(plan),
            admissions: AtomicU64::new(0),
            log: Mutex::new(Vec::new()),
        }
    }

    /// Scripted mode for deterministic unit tests: admission `i` gets
    /// `scripts[i]`; admissions past the end are clean.
    pub fn scripted(inner: &'a E, scripts: Vec<FaultScript>) -> FaultyEngine<'a, E> {
        FaultyEngine {
            inner,
            scripts: Scripts::Explicit(scripts),
            admissions: AtomicU64::new(0),
            log: Mutex::new(Vec::new()),
        }
    }

    /// Admissions attempted so far.
    pub fn admitted(&self) -> u64 {
        self.admissions.load(Ordering::SeqCst)
    }

    /// The scripts handed out, in admission order.
    pub fn injected(&self) -> Vec<FaultScript> {
        self.log.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn script_for(&self, admission: u64) -> FaultScript {
        match &self.scripts {
            Scripts::Seeded(plan) => plan.script(admission),
            Scripts::Explicit(list) => {
                list.get(admission as usize).copied().unwrap_or_else(FaultScript::clean)
            }
        }
    }
}

/// A wrapped slot: the inner slot plus its fate and step count.
pub struct FaultySlot<S> {
    inner: Option<S>,
    script: FaultScript,
    steps: usize,
}

impl<'a, E: SlotEngine> SlotEngine for FaultyEngine<'a, E> {
    type Slot = FaultySlot<E::Slot>;

    fn slot_seq_len(&self) -> usize {
        self.inner.slot_seq_len()
    }

    fn admit(&self, src_row: &[i32]) -> Result<FaultySlot<E::Slot>> {
        let n = self.admissions.fetch_add(1, Ordering::SeqCst);
        let script = self.script_for(n);
        self.log.lock().unwrap_or_else(|e| e.into_inner()).push(script);
        if script.born_poisoned {
            if script.panics {
                panic!("faultkit: admission {n} born poisoned (panic)");
            }
            anyhow::bail!("faultkit: admission {n} born poisoned");
        }
        // Stalling slots never touch the inner engine: they just burn
        // scheduler steps until a deadline reclaims them.
        let inner = if script.stalls { None } else { Some(self.inner.admit(src_row)?) };
        Ok(FaultySlot { inner, script, steps: 0 })
    }

    fn step(&self, slots: &mut [&mut FaultySlot<E::Slot>]) -> Result<()> {
        // Fault check BEFORE any mutation: a failed/panicked step leaves
        // every slot untouched, so the batcher's solo re-step sees the
        // same scripted outcome (the re-steppable contract).
        for s in slots.iter() {
            if s.script.fault_at_step == Some(s.steps) {
                if s.script.panics {
                    panic!("faultkit: scripted panic at step {}", s.steps);
                }
                anyhow::bail!("faultkit: scripted fault at step {}", s.steps);
            }
        }
        let mut live: Vec<&mut E::Slot> = Vec::with_capacity(slots.len());
        for s in slots.iter_mut() {
            if let Some(inner) = s.inner.as_mut() {
                live.push(inner);
            }
        }
        if !live.is_empty() {
            self.inner.step(&mut live)?;
        }
        for s in slots.iter_mut() {
            s.steps += 1;
        }
        Ok(())
    }

    fn slot_complete(&self, slot: &FaultySlot<E::Slot>) -> bool {
        match &slot.inner {
            Some(inner) => self.inner.slot_complete(inner),
            None => false, // stalled: never completes
        }
    }

    fn slot_output(&self, slot: &FaultySlot<E::Slot>) -> Vec<i32> {
        match &slot.inner {
            Some(inner) => self.inner.slot_output(inner),
            None => Vec::new(),
        }
    }

    // KV memory accounting passes straight through: chaos runs see the
    // inner engine's real pool, so memory-pressure soaks can combine
    // scripted faults with a tight byte budget.

    fn kv_stats(&self) -> Option<crate::runtime::KvMemStats> {
        self.inner.kv_stats()
    }

    fn slot_worst_bytes(&self) -> usize {
        self.inner.slot_worst_bytes()
    }

    fn slot_next_step_bytes(&self, slot: &FaultySlot<E::Slot>) -> usize {
        // A stalled slot holds no inner state, so it demands no pages.
        slot.inner.as_ref().map(|s| self.inner.slot_next_step_bytes(s)).unwrap_or(0)
    }

    fn release_slot(&self, slot: &mut FaultySlot<E::Slot>) {
        if let Some(s) = slot.inner.as_mut() {
            self.inner.release_slot(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial inner engine: completes after `row[0]` steps, output is
    /// the framed row plus a step count.
    struct Inner {
        seq: usize,
    }

    struct InnerSlot {
        need: usize,
        tag: i32,
        steps: usize,
    }

    impl SlotEngine for Inner {
        type Slot = InnerSlot;
        fn slot_seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&self, src_row: &[i32]) -> Result<InnerSlot> {
            anyhow::ensure!(src_row.len() == self.seq, "framing");
            Ok(InnerSlot { need: src_row[0] as usize, tag: src_row[1], steps: 0 })
        }
        fn step(&self, slots: &mut [&mut InnerSlot]) -> Result<()> {
            for s in slots.iter_mut() {
                s.steps += 1;
            }
            Ok(())
        }
        fn slot_complete(&self, slot: &InnerSlot) -> bool {
            slot.steps >= slot.need
        }
        fn slot_output(&self, slot: &InnerSlot) -> Vec<i32> {
            vec![slot.tag, slot.steps as i32]
        }
    }

    fn row(need: i32, tag: i32, seq: usize) -> Vec<i32> {
        let mut r = vec![0; seq];
        r[0] = need;
        r[1] = tag;
        r
    }

    #[test]
    fn scripts_are_pure_in_seed_and_admission() {
        let plan = FaultPlan {
            seed: 0xC0FFEE,
            admit_fault: 0.2,
            step_fault: 0.3,
            panic_frac: 0.5,
            stall: 0.1,
        };
        for adm in 0..64u64 {
            assert_eq!(plan.script(adm), plan.script(adm), "same (seed, admission) same script");
        }
        // And the seed actually matters: at these rates 64 admissions
        // can't all agree across two independent seeds.
        let other = FaultPlan { seed: 0xBEEF, ..plan };
        assert!(
            (0..64u64).any(|a| plan.script(a) != other.script(a)),
            "different seeds must differ somewhere"
        );
    }

    #[test]
    fn plan_rates_are_roughly_respected() {
        let plan = FaultPlan {
            seed: 7,
            admit_fault: 0.25,
            step_fault: 0.25,
            panic_frac: 0.5,
            stall: 0.25,
        };
        let n = 2000u64;
        let mut poisoned = 0;
        let mut stalled = 0;
        let mut stepf = 0;
        let mut clean = 0;
        for a in 0..n {
            let s = plan.script(a);
            match (s.born_poisoned, s.stalls, s.fault_at_step) {
                (true, _, _) => poisoned += 1,
                (_, true, _) => stalled += 1,
                (_, _, Some(_)) => stepf += 1,
                _ => clean += 1,
            }
        }
        for (label, count) in
            [("poisoned", poisoned), ("stalled", stalled), ("step-fault", stepf), ("clean", clean)]
        {
            let frac = count as f64 / n as f64;
            assert!((frac - 0.25).abs() < 0.05, "{label} rate {frac} should be ~0.25");
        }
    }

    #[test]
    fn quiet_plan_is_a_transparent_passthrough() {
        let inner = Inner { seq: 4 };
        let faulty = FaultyEngine::new(&inner, FaultPlan::quiet(1));
        let mut slot = faulty.admit(&row(2, 9, 4)).unwrap();
        assert!(!faulty.slot_complete(&slot));
        faulty.step(&mut [&mut slot]).unwrap();
        faulty.step(&mut [&mut slot]).unwrap();
        assert!(faulty.slot_complete(&slot));
        assert_eq!(faulty.slot_output(&slot), vec![9, 2], "bit-identical to the inner engine");
        assert_eq!(faulty.admitted(), 1);
    }

    #[test]
    fn born_poisoned_admission_fails_without_touching_inner() {
        let inner = Inner { seq: 4 };
        let script = FaultScript { born_poisoned: true, ..FaultScript::clean() };
        let faulty = FaultyEngine::scripted(&inner, vec![script]);
        let err = faulty.admit(&row(1, 5, 4)).unwrap_err();
        assert!(err.to_string().contains("born poisoned"));
        // The next admission (beyond the script list) is clean.
        let slot = faulty.admit(&row(1, 6, 4)).unwrap();
        assert_eq!(faulty.slot_output(&slot), vec![6, 0]);
        assert_eq!(faulty.injected().len(), 2);
    }

    #[test]
    fn scripted_step_fault_is_resteppable() {
        let inner = Inner { seq: 4 };
        let script = FaultScript { fault_at_step: Some(1), ..FaultScript::clean() };
        let faulty = FaultyEngine::scripted(&inner, vec![script, FaultScript::clean()]);
        let mut bad = faulty.admit(&row(3, 1, 4)).unwrap();
        let mut good = faulty.admit(&row(3, 2, 4)).unwrap();
        faulty.step(&mut [&mut bad, &mut good]).unwrap();
        // Step 1: the batched step fails because `bad` reached its
        // scripted step; neither slot advances (fault checked pre-mutation).
        assert!(faulty.step(&mut [&mut bad, &mut good]).is_err());
        // Solo re-step attribution: `bad` fails again (same scripted
        // outcome), `good` advances normally.
        assert!(faulty.step(&mut [&mut bad]).is_err());
        faulty.step(&mut [&mut good]).unwrap();
        faulty.step(&mut [&mut good]).unwrap();
        assert!(faulty.slot_complete(&good));
        assert_eq!(faulty.slot_output(&good), vec![2, 3], "untouched by its neighbor's fault");
    }

    #[test]
    fn stalling_slot_never_completes() {
        let inner = Inner { seq: 4 };
        let script = FaultScript { stalls: true, ..FaultScript::clean() };
        let faulty = FaultyEngine::scripted(&inner, vec![script]);
        let mut slot = faulty.admit(&row(1, 5, 4)).unwrap();
        for _ in 0..32 {
            faulty.step(&mut [&mut slot]).unwrap();
        }
        assert!(!faulty.slot_complete(&slot), "stall means never complete, only deadlines help");
    }

    #[test]
    fn panic_scripts_panic_instead_of_erroring() {
        let inner = Inner { seq: 4 };
        let script =
            FaultScript { fault_at_step: Some(0), panics: true, ..FaultScript::clean() };
        let faulty = FaultyEngine::scripted(&inner, vec![script]);
        let mut slot = faulty.admit(&row(1, 5, 4)).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = faulty.step(&mut [&mut slot]);
        }));
        assert!(caught.is_err(), "scripted panic must actually panic");
    }
}
