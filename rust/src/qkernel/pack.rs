//! Bit-packing primitives for sub-8-bit integer rows.
//!
//! Layout: within one logical row, `wl`-bit two's-complement values are
//! laid down LSB-first into consecutive `u32` words — value `j` occupies
//! bits `[j*wl, (j+1)*wl)` of the row's bit stream, crossing word
//! boundaries when `wl` does not divide 32 (true bit-packing, no per-word
//! padding). Every row starts on a fresh word, so rows are independent
//! slices of `words_per_row` words and can be packed/unpacked (and
//! streamed by the GEMM panel loop) without touching their neighbours.

/// `u32` words needed for one bit-packed row of `cols` `wl`-bit values.
pub fn words_per_row(cols: usize, wl: u32) -> usize {
    (cols * wl as usize).div_ceil(32)
}

/// Pack one row of grid values into `out` (`words_per_row(vals.len(), wl)`
/// words, zeroed and filled). Values must fit `wl`-bit two's complement;
/// the symmetric grids stored here (`|q| <= 2^(wl-1) - 1`) always do.
pub fn pack_row(vals: &[i8], wl: u32, out: &mut [u32]) {
    debug_assert_eq!(out.len(), words_per_row(vals.len(), wl));
    debug_assert!((2..=8).contains(&wl));
    for w in out.iter_mut() {
        *w = 0;
    }
    let mask = (1u32 << wl) - 1;
    let mut word = 0usize;
    let mut shift = 0u32;
    for &v in vals {
        let bits = (v as u32) & mask;
        out[word] |= bits << shift;
        let room = 32 - shift;
        if wl > room {
            // Value straddles the word edge; `room` is in 1..=31 here.
            out[word + 1] |= bits >> room;
        }
        shift += wl;
        if shift >= 32 {
            shift -= 32;
            word += 1;
        }
    }
}

/// Unpack (sign-extend) values `j0..j1` of a packed row into `out`
/// (`j1 - j0` entries). `row` is the row's full word slice.
pub fn unpack_range_into(row: &[u32], j0: usize, j1: usize, wl: u32, out: &mut [i32]) {
    debug_assert_eq!(out.len(), j1 - j0);
    debug_assert!((2..=8).contains(&wl));
    let sh = 32 - wl;
    let off = j0 * wl as usize;
    let mut word = off / 32;
    let mut shift = (off % 32) as u32;
    for o in out.iter_mut() {
        let mut bits = row[word] >> shift;
        let room = 32 - shift;
        if wl > room {
            bits |= row[word + 1] << room;
        }
        *o = ((bits << sh) as i32) >> sh;
        shift += wl;
        if shift >= 32 {
            shift -= 32;
            word += 1;
        }
    }
}

/// Single packed value at position `j` of a row (sign-extended).
pub fn unpack_one(row: &[u32], j: usize, wl: u32) -> i32 {
    let off = j * wl as usize;
    let word = off / 32;
    let shift = (off % 32) as u32;
    let mut bits = row[word] >> shift;
    let room = 32 - shift;
    if wl > room {
        bits |= row[word + 1] << room;
    }
    let sh = 32 - wl;
    ((bits << sh) as i32) >> sh
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(vals: &[i8], wl: u32) {
        let mut words = vec![0u32; words_per_row(vals.len(), wl)];
        pack_row(vals, wl, &mut words);
        let mut back = vec![0i32; vals.len()];
        unpack_range_into(&words, 0, vals.len(), wl, &mut back);
        for (j, (&v, &b)) in vals.iter().zip(&back).enumerate() {
            assert_eq!(v as i32, b, "wl={wl} j={j} of {} vals", vals.len());
            assert_eq!(unpack_one(&words, j, wl), v as i32, "unpack_one wl={wl} j={j}");
        }
    }

    #[test]
    fn roundtrip_all_widths_and_awkward_lengths() {
        // Lengths chosen to hit word-aligned, straddling and tail cases
        // for every width (e.g. 3-bit values cross a word edge every
        // 32/gcd(3,32) values; length 11 leaves a 1-bit tail).
        for wl in 2..=8u32 {
            let lv = (1i32 << (wl - 1)) - 1;
            for len in [1usize, 2, 3, 5, 7, 8, 10, 11, 16, 31, 32, 33, 65] {
                let vals: Vec<i8> = (0..len)
                    .map(|j| {
                        let span = 2 * lv + 1;
                        ((j as i32 * 7 + 3) % span - lv) as i8
                    })
                    .collect();
                roundtrip(&vals, wl);
            }
        }
    }

    #[test]
    fn roundtrip_extremes() {
        for wl in 2..=8u32 {
            let lv = ((1i32 << (wl - 1)) - 1) as i8;
            roundtrip(&vec![lv; 40], wl);
            roundtrip(&vec![-lv; 40], wl);
            roundtrip(&vec![0i8; 40], wl);
        }
    }

    #[test]
    fn range_unpack_matches_full_unpack() {
        let wl = 5u32;
        let vals: Vec<i8> = (0..50).map(|j| ((j * 11 + 1) % 31 - 15) as i8).collect();
        let mut words = vec![0u32; words_per_row(vals.len(), wl)];
        pack_row(&vals, wl, &mut words);
        for (j0, j1) in [(0usize, 50usize), (3, 17), (31, 32), (13, 50), (49, 50)] {
            let mut out = vec![0i32; j1 - j0];
            unpack_range_into(&words, j0, j1, wl, &mut out);
            for (o, &v) in out.iter().zip(&vals[j0..j1]) {
                assert_eq!(*o, v as i32, "range {j0}..{j1}");
            }
        }
    }

    #[test]
    fn word_counts() {
        assert_eq!(words_per_row(8, 4), 1); // exactly one word
        assert_eq!(words_per_row(9, 4), 2);
        assert_eq!(words_per_row(10, 3), 1); // 30 bits
        assert_eq!(words_per_row(11, 3), 2); // 33 bits
        assert_eq!(words_per_row(1, 2), 1);
        assert_eq!(words_per_row(0, 7), 0);
    }
}
