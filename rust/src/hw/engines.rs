//! MatMul engine designs (§V): baseline dense, Single SVD, Cascade SVD.
//!
//! * **Baseline** — one tiled engine computing `X W` dense (Fig. 5).
//! * **Single SVD** (Fig. 6 left) — one engine reused temporally for
//!   `X W1` then `(X W1) W2`; the `N_t` tiling factor is shared between
//!   the R- and N-parallel phases, and the whole `M_t x R` intermediate
//!   tile is buffered on-chip between the phases.
//! * **Cascade SVD** (Fig. 6 right) — two engines spatially unrolled, with
//!   independent `R_t`/`N_t` tiling but a shared `M_t` (no re-buffering at
//!   the seam); stages overlap, so latency is the slower stage's.
//!
//! Off-chip traffic never includes the intermediate (that is the point of
//! both schedules); the bandwidth requirement is Eq. 19 over the full run.

use super::perf::{port_words, tile_latency_cycles};
use super::resources::{intermediate_buffer_bram, tile_resources};
use super::{Platform, Resources, TileConfig, Workload};

/// Which engine architecture a design point uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Baseline,
    SingleSvd,
    CascadeSvd,
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Baseline => write!(f, "Baseline"),
            EngineKind::SingleSvd => write!(f, "SingleSVD"),
            EngineKind::CascadeSvd => write!(f, "CascadeSVD"),
        }
    }
}

/// A fully evaluated hardware design point.
#[derive(Debug, Clone, Copy)]
pub struct EngineDesign {
    pub kind: EngineKind,
    /// First (or only) engine tile.
    pub tile1: TileConfig,
    /// Second engine tile (Cascade only).
    pub tile2: Option<TileConfig>,
    /// Full-throughput latency in cycles (Eq. 15 composition).
    pub latency_cycles: f64,
    /// DSP + BRAM including intermediate buffers.
    pub resources: Resources,
    /// Off-chip bandwidth requirement to run at full throughput,
    /// bits/cycle (Eq. 19: total traffic / latency).
    pub bandwidth_req: f64,
    /// Total off-chip traffic in bits (intermediates excluded for the SVD
    /// engines — that is the point of their schedules).
    pub offchip_bits: f64,
}

impl EngineDesign {
    /// Dense baseline engine on workload `w`.
    pub fn baseline(w: &Workload, t: TileConfig) -> EngineDesign {
        let p = tile_latency_cycles(w, &t);
        let bits = p.words.0 * w.a_bits as f64
            + p.words.1 * w.w_bits as f64
            + p.words.2 * w.a_bits as f64;
        EngineDesign {
            kind: EngineKind::Baseline,
            tile1: t,
            tile2: None,
            latency_cycles: p.latency_cycles,
            resources: tile_resources(w, &t),
            bandwidth_req: p.bandwidth_bits_per_cycle,
            offchip_bits: bits,
        }
    }

    /// Single SVD engine: temporal reuse over `X W1` (`M x K x r`) then
    /// `(X W1) W2` (`M x r x N`).
    pub fn single_svd(w: &Workload, rank: usize, t: TileConfig) -> EngineDesign {
        let s1 = Workload::new(w.m, w.k, rank, w.w_bits, w.a_bits);
        let s2 = Workload::new(w.m, rank, w.n, w.w_bits, w.a_bits);
        let p1 = tile_latency_cycles(&s1, &t);
        let p2 = tile_latency_cycles(&s2, &t);
        let latency = p1.latency_cycles + p2.latency_cycles;

        // Off-chip traffic: stage-1 LHS + RHS, stage-2 RHS + OUT. The
        // M_t x r intermediate stays on-chip (both directions free).
        let w1 = port_words(&s1, &t);
        let w2 = port_words(&s2, &t);
        let bits = w1.0 * w.a_bits as f64
            + w1.1 * w.w_bits as f64
            + w2.1 * w.w_bits as f64
            + w2.2 * w.a_bits as f64;

        let mut res = tile_resources(&s1, &t);
        // Engine is reused; resources are the max of the two phases, not
        // the sum (same PEs, same FIFOs) ...
        let res2 = tile_resources(&s2, &t);
        res.dsp = res.dsp.max(res2.dsp);
        res.bram18k = res.bram18k.max(res2.bram18k);
        // ... plus the M_t x R intermediate buffer (activation-width).
        res.bram18k += intermediate_buffer_bram(t.mt, rank, w.a_bits);

        EngineDesign {
            kind: EngineKind::SingleSvd,
            tile1: t,
            tile2: None,
            latency_cycles: latency,
            resources: res,
            bandwidth_req: bits / latency,
            offchip_bits: bits,
        }
    }

    /// Cascade SVD engine: stage 1 tile `M_t x R_t`, stage 2 tile
    /// `M_t x N_t` (shared `M_t`), overlapped execution.
    pub fn cascade_svd(
        w: &Workload,
        rank: usize,
        t1: TileConfig,
        t2: TileConfig,
    ) -> EngineDesign {
        assert_eq!(t1.mt, t2.mt, "cascade engines must share M_t (§V-B)");
        let s1 = Workload::new(w.m, w.k, rank, w.w_bits, w.a_bits);
        let s2 = Workload::new(w.m, rank, w.n, w.w_bits, w.a_bits);
        let p1 = tile_latency_cycles(&s1, &t1);
        let p2 = tile_latency_cycles(&s2, &t2);
        // Pipelined stages: steady-state throughput is set by the slower
        // stage; the faster stage's first tile adds a fill bubble of one
        // M-tile's worth of its latency.
        let m_tiles = super::ceil_div(w.m, t1.mt) as f64;
        let fill = p1.latency_cycles.min(p2.latency_cycles) / m_tiles;
        let latency = p1.latency_cycles.max(p2.latency_cycles) + fill;

        let w1 = port_words(&s1, &t1);
        let w2 = port_words(&s2, &t2);
        // Both stages stream concurrently: traffic adds over the shared
        // wall clock.
        let bits = w1.0 * w.a_bits as f64
            + w1.1 * w.w_bits as f64
            + w2.1 * w.w_bits as f64
            + w2.2 * w.a_bits as f64;
        let bw = bits / latency;

        let res = tile_resources(&s1, &t1).add(tile_resources(&s2, &t2));
        let res = Resources {
            dsp: res.dsp,
            bram18k: res.bram18k + intermediate_buffer_bram(t1.mt, rank, w.a_bits),
        };

        EngineDesign {
            kind: EngineKind::CascadeSvd,
            tile1: t1,
            tile2: Some(t2),
            latency_cycles: latency,
            resources: res,
            bandwidth_req: bw,
            offchip_bits: bits,
        }
    }

    /// Effective latency on `platform`: when the platform cannot supply
    /// the design's full-throughput bandwidth, the engine stalls and
    /// latency stretches proportionally.
    pub fn effective_latency(&self, platform: &Platform) -> f64 {
        let slowdown = (self.bandwidth_req / platform.bandwidth_bits_per_cycle).max(1.0);
        self.latency_cycles * slowdown
    }

    /// Does this design fit the platform's DSP/BRAM budget?
    pub fn fits(&self, platform: &Platform) -> bool {
        self.resources.fits(platform.dsp, platform.bram18k)
    }
}

/// Convenience constructors used by the DSE sweep.
pub struct SingleSvdEngine;
pub struct CascadeSvdEngine;

#[cfg(test)]
mod tests {
    use super::*;

    fn w() -> Workload {
        Workload::new(512, 512, 512, 4, 8)
    }

    #[test]
    fn svd_reduces_latency_at_low_rank() {
        // Fig. 10's core effect: at rank 128 the SVD engines need ~half
        // the MACs of the dense baseline, so comparable tiles run faster.
        let t = TileConfig::new(16, 16, 8);
        let base = EngineDesign::baseline(&w(), t);
        let single = EngineDesign::single_svd(&w(), 128, t);
        assert!(
            single.latency_cycles < base.latency_cycles,
            "single {} vs base {}",
            single.latency_cycles,
            base.latency_cycles
        );
    }

    #[test]
    fn cascade_overlaps_stages() {
        let t1 = TileConfig::new(16, 16, 8);
        let t2 = TileConfig::new(16, 16, 8);
        let cas = EngineDesign::cascade_svd(&w(), 128, t1, t2);
        let single_equiv = EngineDesign::single_svd(&w(), 128, t2);
        // Cascade spends more resources but must beat the serialized
        // single engine when its stages are balanced.
        assert!(cas.latency_cycles < single_equiv.latency_cycles);
        assert!(cas.resources.dsp > single_equiv.resources.dsp);
    }

    #[test]
    #[should_panic]
    fn cascade_requires_shared_mt() {
        let _ = EngineDesign::cascade_svd(
            &w(),
            128,
            TileConfig::new(8, 8, 8),
            TileConfig::new(16, 16, 8),
        );
    }

    #[test]
    fn svd_lowers_offchip_traffic() {
        // Lower-rank weights move fewer off-chip bits in total — the
        // mechanism behind Fig. 10's bandwidth-limited region (a design
        // can trade the saved traffic for a smaller port at equal
        // latency; the DSE sweep surfaces those points).
        let t = TileConfig::new(8, 8, 4);
        let base = EngineDesign::baseline(&w(), t);
        let single = EngineDesign::single_svd(&w(), 64, t);
        assert!(single.offchip_bits < 0.5 * base.offchip_bits);
        // Under a starved platform the traffic advantage becomes a
        // latency advantage.
        let starved = Platform {
            bandwidth_bits_per_cycle: 8.0,
            ..Platform::zcu111()
        };
        assert!(single.effective_latency(&starved) < base.effective_latency(&starved));
    }

    #[test]
    fn effective_latency_stretches_under_starvation() {
        let t = TileConfig::new(32, 32, 16);
        let base = EngineDesign::baseline(&w(), t);
        let full = Platform::zcu111();
        let quarter = Platform::zcu111_quarter_bw();
        assert!(base.effective_latency(&quarter) >= base.effective_latency(&full));
    }

    #[test]
    fn rank_full_svd_costs_more_than_dense() {
        // At full rank the decomposition doubles the MACs — the engine
        // must not pretend otherwise.
        let t = TileConfig::new(16, 16, 8);
        let base = EngineDesign::baseline(&w(), t);
        let single = EngineDesign::single_svd(&w(), 512, t);
        assert!(single.latency_cycles > base.latency_cycles);
    }
}
