//! `weights_<pair>.bin` reader — the flat binary weight store written by
//! `python/compile/train.py::save_weights`.
//!
//! Layout: magic `ITWB` | u32 n_entries | entries, where each entry is
//! u32 name_len | name | u32 ndim | u32 dims[ndim] | f32 data (LE).
//! 1-D tensors (layer-norm params) are stored as `1 x n` matrices.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;

/// All tensors of one trained model, by name.
#[derive(Debug, Clone)]
pub struct WeightStore {
    /// Matrix plus the ndim it was stored with (1-D tensors become `1 x n`
    /// matrices but must be fed back to PJRT with 1-D dims).
    entries: BTreeMap<String, (Matrix, usize)>,
}

impl WeightStore {
    /// Empty store; fill with [`Self::insert`] / [`Self::insert_vec`]
    /// (the testkit tiny-model generator and round-trip tests build
    /// stores in-process instead of shelling out to Python).
    pub fn new() -> WeightStore {
        WeightStore { entries: BTreeMap::new() }
    }

    /// Insert a 2-D tensor (replaces any previous entry of that name).
    pub fn insert(&mut self, name: &str, m: Matrix) {
        self.entries.insert(name.to_string(), (m, 2));
    }

    /// Insert a 1-D tensor (stored as a `1 x n` matrix, like the reader).
    pub fn insert_vec(&mut self, name: &str, v: Vec<f32>) {
        let n = v.len();
        self.entries.insert(name.to_string(), (Matrix::from_vec(1, n, v), 1));
    }

    pub fn load(path: impl AsRef<Path>) -> Result<WeightStore> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading weight store {:?}", path.as_ref()))?;
        Self::parse(&bytes)
    }

    /// Serialize in the exact ITWB layout `train.py::save_weights` emits
    /// (entries in sorted-name order, which the `BTreeMap` gives for free).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, (m, ndim)) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(*ndim as u32).to_le_bytes());
            if *ndim == 1 {
                out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
            } else {
                out.extend_from_slice(&(m.rows() as u32).to_le_bytes());
                out.extend_from_slice(&(m.cols() as u32).to_le_bytes());
            }
            for &x in m.data() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_bytes())
            .with_context(|| format!("writing weight store {:?}", path.as_ref()))
    }

    pub fn parse(bytes: &[u8]) -> Result<WeightStore> {
        let mut cur = Cursor { b: bytes, pos: 0 };
        if cur.take(4)? != b"ITWB" {
            bail!("bad magic: not an ITWB weight store");
        }
        let n = cur.u32()? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let name_len = cur.u32()? as usize;
            let name = String::from_utf8(cur.take(name_len)?.to_vec())
                .context("weight name not utf-8")?;
            let ndim = cur.u32()? as usize;
            if ndim == 0 || ndim > 2 {
                bail!("weight {name}: unsupported ndim {ndim}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(cur.u32()? as usize);
            }
            let (rows, cols) = if ndim == 1 { (1, dims[0]) } else { (dims[0], dims[1]) };
            let count = rows * cols;
            let raw = cur.take(count * 4)?;
            let mut data = Vec::with_capacity(count);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            entries.insert(name, (Matrix::from_vec(rows, cols, data), ndim));
        }
        if cur.pos != bytes.len() {
            bail!("trailing bytes in weight store");
        }
        Ok(WeightStore { entries })
    }

    pub fn get(&self, name: &str) -> Option<&Matrix> {
        self.entries.get(name).map(|(m, _)| m)
    }

    /// Reject NaN/Inf weight values at load time, naming the offending
    /// tensor, flat index and shape. A single non-finite entry would
    /// otherwise propagate silently through every matmul and surface
    /// as garbage tokens deep in decode — fail at the source instead.
    pub fn check_finite(&self) -> Result<()> {
        for (name, (m, _)) in &self.entries {
            if let Some(i) = m.data().iter().position(|x| !x.is_finite()) {
                let (rows, cols) = m.shape();
                bail!(
                    "tensor {name} ({rows}x{cols}) has non-finite value {} at flat index {i}",
                    m.data()[i]
                );
            }
        }
        Ok(())
    }

    /// PJRT dims for a tensor: `[n]` for stored-1-D, `[rows, cols]` else.
    pub fn dims(&self, name: &str) -> Option<Vec<usize>> {
        self.entries.get(name).map(|(m, ndim)| {
            if *ndim == 1 {
                vec![m.cols()]
            } else {
                vec![m.rows(), m.cols()]
            }
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for WeightStore {
    fn default() -> Self {
        Self::new()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated weight store at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a store in-memory in the same format train.py writes.
    fn synth_store(entries: &[(&str, usize, usize)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (i, (name, r, c)) in entries.iter().enumerate() {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&2u32.to_le_bytes());
            out.extend_from_slice(&(*r as u32).to_le_bytes());
            out.extend_from_slice(&(*c as u32).to_le_bytes());
            for k in 0..r * c {
                out.extend_from_slice(&((i * 1000 + k) as f32).to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_synthetic() {
        let bytes = synth_store(&[("a.w", 2, 3), ("b.w", 1, 4)]);
        let s = WeightStore::parse(&bytes).unwrap();
        assert_eq!(s.len(), 2);
        let a = s.get("a.w").unwrap();
        assert_eq!(a.shape(), (2, 3));
        assert_eq!(a.get(1, 2), 5.0);
        assert_eq!(s.dims("a.w").unwrap(), vec![2, 3]);
        assert!(s.get("missing").is_none());
    }

    #[test]
    fn one_dim_entries_keep_their_dims() {
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(4u32).to_le_bytes());
        out.extend_from_slice(b"ln_g");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&5u32.to_le_bytes());
        for k in 0..5 {
            out.extend_from_slice(&(k as f32).to_le_bytes());
        }
        let s = WeightStore::parse(&out).unwrap();
        assert_eq!(s.get("ln_g").unwrap().shape(), (1, 5));
        assert_eq!(s.dims("ln_g").unwrap(), vec![5]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(WeightStore::parse(b"XXXX").is_err());
        let mut bytes = synth_store(&[("a", 2, 2)]);
        bytes.truncate(bytes.len() - 3);
        assert!(WeightStore::parse(&bytes).is_err());
        bytes.push(0);
        assert!(WeightStore::parse(&bytes).is_err());
    }

    #[test]
    fn round_trips_through_writer() {
        let mut s = WeightStore::new();
        s.insert("enc0.self_q", Matrix::from_vec(2, 3, vec![1., -2., 3., 4., 5., -6.]));
        s.insert_vec("enc0.ln1_g", vec![0.5, 1.5, 2.5]);
        s.insert("zz.last", Matrix::from_vec(1, 1, vec![9.0]));
        let bytes = s.to_bytes();
        let r = WeightStore::parse(&bytes).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(r.get("enc0.self_q").unwrap().data(), s.get("enc0.self_q").unwrap().data());
        // 1-D entries keep 1-D dims through the round trip.
        assert_eq!(r.dims("enc0.ln1_g").unwrap(), vec![3]);
        assert_eq!(r.get("enc0.ln1_g").unwrap().shape(), (1, 3));
        // Byte-stable: serializing the reparse reproduces the bytes.
        assert_eq!(r.to_bytes(), bytes);
    }

    #[test]
    fn save_and_load_file_round_trip() {
        let path = std::env::temp_dir().join("itera_weights_roundtrip.bin");
        let mut s = WeightStore::new();
        s.insert("w", Matrix::from_vec(3, 2, (0..6).map(|i| i as f32).collect()));
        s.save(&path).unwrap();
        let r = WeightStore::load(&path).unwrap();
        assert_eq!(r.get("w").unwrap().shape(), (3, 2));
        assert_eq!(r.get("w").unwrap().get(2, 1), 5.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_finite_names_the_bad_tensor() {
        let mut s = WeightStore::new();
        s.insert("enc0.ok", Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]));
        assert!(s.check_finite().is_ok());
        s.insert("dec1.bad", Matrix::from_vec(1, 3, vec![0.5, f32::NAN, 1.5]));
        let err = s.check_finite().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("dec1.bad"), "names the tensor: {msg}");
        assert!(msg.contains("index 1"), "names the position: {msg}");
        s.insert("dec1.bad", Matrix::from_vec(1, 2, vec![f32::INFINITY, 0.0]));
        assert!(s.check_finite().is_err(), "Inf is rejected too");
    }

    #[test]
    fn rejects_non_utf8_name() {
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&[0xFF, 0xFE]); // invalid utf-8 name bytes
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1.0f32.to_le_bytes());
        let err = WeightStore::parse(&out).unwrap_err();
        assert!(format!("{err:#}").contains("utf-8"), "{err:#}");
    }

    #[test]
    fn rejects_truncated_entry_and_bad_ndim() {
        // Entry header declares a name longer than the remaining bytes.
        let mut out = Vec::new();
        out.extend_from_slice(b"ITWB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&100u32.to_le_bytes());
        out.extend_from_slice(b"ab");
        assert!(WeightStore::parse(&out).is_err());
        // ndim outside 1..=2 is rejected, not misparsed.
        for ndim in [0u32, 3] {
            let mut out = Vec::new();
            out.extend_from_slice(b"ITWB");
            out.extend_from_slice(&1u32.to_le_bytes());
            out.extend_from_slice(&1u32.to_le_bytes());
            out.extend_from_slice(b"x");
            out.extend_from_slice(&ndim.to_le_bytes());
            assert!(WeightStore::parse(&out).is_err(), "ndim {ndim}");
        }
    }

    #[test]
    fn loads_real_weights() {
        let dir = crate::model::Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = crate::model::Manifest::load(&dir).unwrap();
        let pair = &m.pairs["en-de"];
        let s = WeightStore::load(&pair.weights).unwrap();
        // Every compressed linear must be present with the declared shape.
        for l in &m.linears {
            let w = s.get(&l.name).unwrap_or_else(|| panic!("{} missing", l.name));
            assert_eq!(w.shape(), (l.k, l.n), "{}", l.name);
        }
        // Embeddings present too.
        assert_eq!(
            s.get("src_emb").unwrap().shape(),
            (m.model.vocab, m.model.d_model)
        );
    }
}
