//! PJRT client + compiled-executable cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// Wraps the PJRT CPU client and caches compiled executables by path, so
/// the coordinator can hand out shared references while figure runners and
/// the serving loop compile each artifact exactly once.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load HLO text from `path`, compile it, and cache the executable.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(exe) = self.cache.lock().unwrap().get(&path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(path, exe.clone());
        Ok(exe)
    }

    /// Upload an f32 tensor to a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    /// Upload an i32 tensor to a device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading i32 buffer")
    }

    /// Execute with device-resident argument buffers; returns the first
    /// output literal of the 1-tuple the AOT path lowers (return_tuple).
    pub fn run_tuple1(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::Literal> {
        let outs = exe.execute_b(args).context("PJRT execute")?;
        let lit = outs[0][0].to_literal_sync().context("fetching output")?;
        lit.to_tuple1().context("unwrapping 1-tuple output")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    #[test]
    fn linear512_artifacts_execute_and_agree() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let eng = Engine::cpu().unwrap();

        // Dense 512x512x512 quant-matmul kernel vs a native Rust matmul.
        let exe = eng.load_hlo(&m.artifacts.linear512_dense).unwrap();
        let mut rng = crate::util::rng::Pcg64::new(99);
        let x = crate::tensor::Matrix::randn(512, 512, &mut rng).scale(0.05);
        let w = crate::tensor::Matrix::randn(512, 512, &mut rng).scale(0.05);
        let bx = eng.upload_f32(x.data(), &[512, 512]).unwrap();
        let bw = eng.upload_f32(w.data(), &[512, 512]).unwrap();
        let out = eng.run_tuple1(&exe, &[&bx, &bw]).unwrap();
        let y: Vec<f32> = out.to_vec().unwrap();
        let want = x.matmul(&w);
        let mut max_err = 0.0f32;
        for (a, b) in y.iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-2, "kernel vs rust matmul max err {max_err}");

        // Cached: second load returns the same Arc.
        let exe2 = eng.load_hlo(&m.artifacts.linear512_dense).unwrap();
        assert!(std::sync::Arc::ptr_eq(&exe, &exe2));
    }

    #[test]
    fn cascade_artifact_matches_two_step_product() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let eng = Engine::cpu().unwrap();
        let exe = eng.load_hlo(&m.artifacts.linear512_svd).unwrap();
        let mut rng = crate::util::rng::Pcg64::new(100);
        let x = crate::tensor::Matrix::randn(512, 512, &mut rng).scale(0.05);
        let w1 = crate::tensor::Matrix::randn(512, 128, &mut rng).scale(0.05);
        let w2 = crate::tensor::Matrix::randn(128, 512, &mut rng).scale(0.05);
        let bx = eng.upload_f32(x.data(), &[512, 512]).unwrap();
        let b1 = eng.upload_f32(w1.data(), &[512, 128]).unwrap();
        let b2 = eng.upload_f32(w2.data(), &[128, 512]).unwrap();
        let out = eng.run_tuple1(&exe, &[&bx, &b1, &b2]).unwrap();
        let y: Vec<f32> = out.to_vec().unwrap();
        let want = x.matmul(&w1).matmul(&w2);
        let mut max_err = 0.0f32;
        for (a, b) in y.iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-2, "cascade vs rust max err {max_err}");
    }
}
