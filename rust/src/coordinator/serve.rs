//! Batched serving demo: a minimal request loop over any translate
//! backend, under either batching discipline.
//!
//! Demonstrates the deployment story: single-sentence translation
//! requests arrive on a channel and are answered with de-framed tokens +
//! latency, by one of two server loops:
//!
//! * **static** ([`serve_loop`]) — group whatever is queued up to the
//!   backend's batch capacity, execute one monolithic translate call per
//!   batch (stragglers pin the batch), respond, repeat. Backend-agnostic
//!   ([`TranslateBackend`]): the same code path serves the always-built
//!   native engine and — with the `pjrt` feature — the AOT-compiled PJRT
//!   session.
//! * **continuous** ([`serve_loop_continuous`]) — drive a
//!   [`ContinuousBatcher`] over any slot engine
//!   ([`crate::runtime::SlotEngine`]): between decode steps, retire
//!   EOS'd slots, admit queued requests into the freed capacity, and
//!   step the mixed-age batch — the decode engine never idles while work
//!   is queued, and responses are **bit-identical** to the static loop's
//!   (slot independence; pinned by the serving soak test).
//!
//! Python is nowhere on either path. The batching logic ([`pack_rows`],
//! [`serve_loop`], the scheduler in `coordinator::scheduler`) is split
//! out of the demo driver so it can be unit-tested against mock backends
//! without threads, models or artifacts.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::eval::{strip_specials, Corpus};
use crate::model::ModelDims;
use crate::runtime::{DecodePolicy, Mode, SlotEngine, TranslateBackend};
use crate::util::rng::Pcg64;
use crate::util::stats::Summary;

use super::scheduler::{Batcher, ContinuousBatcher};

#[cfg(feature = "pjrt")]
use crate::runtime::{PjrtBackend, TranslateSession};

#[cfg(feature = "pjrt")]
use super::Coordinator;
use super::Method;

/// One translation request: source tokens in, (tokens, latency_s) out.
pub struct Request {
    pub tokens: Vec<i32>,
    pub t_arrival: Instant,
    pub respond: mpsc::Sender<(Vec<i32>, f64)>,
}

/// Aggregate outcome of one [`serve_loop`] / [`serve_loop_continuous`]
/// run.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Responses sent. Balances [`received`](Self::received) on a clean
    /// run: every request taken off the channel is answered exactly once.
    pub served: usize,
    /// Requests taken off the channel.
    pub received: usize,
    /// Static loop: translate calls. Continuous loop: decode steps.
    pub batches: usize,
    pub wall_s: f64,
    /// Generated (de-framed) output tokens across all responses — the
    /// numerator of the serving throughput number.
    pub tokens: usize,
    /// Per-request latency samples (seconds, arrival to response), as
    /// observed by the server loop itself.
    pub latency: Summary,
    /// Mean fraction of batch/slot capacity occupied per translate call
    /// (static) or decode step (continuous), in `[0, 1]`.
    pub occupancy: f64,
}

impl ServeStats {
    /// Generated tokens per wall-clock second over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-12)
    }
}

/// Pack up to `batch` token rows into a fixed `[batch * seq]` buffer:
/// rows are truncated to `seq` and the remainder is PAD-filled (both the
/// tail of short rows and the unused batch slots).
pub fn pack_rows(rows: &[&[i32]], batch: usize, seq: usize, pad: i32) -> Vec<i32> {
    assert!(rows.len() <= batch, "{} rows exceed batch capacity {batch}", rows.len());
    let mut src = vec![pad; batch * seq];
    for (row, tokens) in rows.iter().enumerate() {
        let take = tokens.len().min(seq);
        src[row * seq..row * seq + take].copy_from_slice(&tokens[..take]);
    }
    src
}

/// Drain one batch from the request channel: block for the **first**
/// request only, then opportunistically take whatever else is already
/// queued, up to `capacity`. `None` when the channel has disconnected.
///
/// Blocking past the first request would be head-of-line blocking — the
/// loop would wait indefinitely for a full batch while admitted clients
/// hold their responses. Partial batches must flush; pinned by the
/// `partial_batch_flushes_without_disconnect` regression test.
fn next_batch(rx: &mpsc::Receiver<Request>, capacity: usize) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    while batch.len() < capacity {
        match rx.try_recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// The server loop: batch requests off `rx`, execute them on `backend`,
/// respond with de-framed tokens + latency, until `n_requests` have been
/// served or the channel disconnects.
pub fn serve_loop(
    backend: &dyn TranslateBackend,
    rx: &mpsc::Receiver<Request>,
    dims: &ModelDims,
    n_requests: usize,
) -> Result<ServeStats> {
    let b = backend.batch();
    let s = backend.seq_len();
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut tokens = 0usize;
    let mut occupied_rows = 0usize;
    let mut latency = Summary::new();
    while served < n_requests {
        let Some(batch) = next_batch(rx, b) else { break };
        occupied_rows += batch.len();
        let rows: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        // Fixed-shape backends (AOT artifacts) need the full compiled
        // batch; variable-shape ones only pay for the rows they got.
        let pack_to = if backend.fixed_shape() { b } else { rows.len() };
        let src = pack_rows(&rows, pack_to, s, dims.pad_id);
        let out = backend.translate(&src)?;
        let now = Instant::now();
        for (row, req) in batch.iter().enumerate() {
            let toks = strip_specials(
                &out[row * s..(row + 1) * s],
                dims.bos_id,
                dims.eos_id,
                dims.pad_id,
            );
            let lat = now.duration_since(req.t_arrival).as_secs_f64();
            tokens += toks.len();
            latency.add(lat);
            req.respond.send((toks, lat)).ok();
        }
        served += batch.len();
        batches += 1;
    }
    Ok(ServeStats {
        served,
        received: served,
        batches,
        wall_s: t0.elapsed().as_secs_f64(),
        tokens,
        latency,
        occupancy: occupied_rows as f64 / (batches * b).max(1) as f64,
    })
}

/// The continuous server loop: drive a [`ContinuousBatcher`] over a slot
/// engine. Each round drains whatever the channel already holds into the
/// admission queue (blocking only when there is nothing live or queued
/// to step), ticks the batcher — retire, admit, one mixed-age decode
/// step — and responds to completions with de-framed tokens + latency.
/// Runs until `n_requests` have been served or the channel disconnects
/// and the backlog drains. Responses are bit-identical to the static
/// loop's for the same requests (slot independence).
pub fn serve_loop_continuous<E: SlotEngine>(
    engine: &E,
    rx: &mpsc::Receiver<Request>,
    dims: &ModelDims,
    n_requests: usize,
    capacity: usize,
) -> Result<ServeStats> {
    let s = engine.slot_seq_len();
    let t0 = Instant::now();
    let mut batcher = ContinuousBatcher::new(engine, capacity);
    let mut inflight: HashMap<u64, Request> = HashMap::new();
    let mut received = 0usize;
    let mut served = 0usize;
    let mut tokens = 0usize;
    let mut latency = Summary::new();
    let mut disconnected = false;
    let mut enqueue = |req: Request,
                       batcher: &mut ContinuousBatcher<E>,
                       inflight: &mut HashMap<u64, Request>| {
        let id = batcher.submit(pack_rows(&[req.tokens.as_slice()], 1, s, dims.pad_id));
        inflight.insert(id, req);
    };
    while served < n_requests {
        // Block for a request only when a tick would be an idle no-op;
        // otherwise drain the channel opportunistically between steps.
        if batcher.idle() {
            if received >= n_requests || disconnected {
                break;
            }
            let Ok(req) = rx.recv() else { break };
            enqueue(req, &mut batcher, &mut inflight);
            received += 1;
        }
        while received < n_requests && !disconnected {
            match rx.try_recv() {
                Ok(req) => {
                    enqueue(req, &mut batcher, &mut inflight);
                    received += 1;
                }
                Err(mpsc::TryRecvError::Disconnected) => disconnected = true,
                Err(mpsc::TryRecvError::Empty) => break,
            }
        }
        let completions = batcher.tick()?;
        let now = Instant::now();
        for c in completions {
            let Some(req) = inflight.remove(&c.id) else { continue };
            let toks = strip_specials(&c.tokens, dims.bos_id, dims.eos_id, dims.pad_id);
            let lat = now.duration_since(req.t_arrival).as_secs_f64();
            tokens += toks.len();
            latency.add(lat);
            req.respond.send((toks, lat)).ok();
            served += 1;
        }
    }
    Ok(ServeStats {
        served,
        received,
        batches: batcher.stats().steps,
        wall_s: t0.elapsed().as_secs_f64(),
        tokens,
        latency,
        occupancy: batcher.occupancy(),
    })
}

/// Spawn the closed-loop demo client: submits `n_requests` random test
/// sentences back-to-back (each waits for its response before the next
/// goes out; the batcher still groups concurrent stragglers). Returns
/// client-observed latencies + the received translations on join.
fn spawn_client(
    corpus: Corpus,
    n_requests: usize,
    tx: mpsc::Sender<Request>,
) -> std::thread::JoinHandle<(Summary, Vec<Vec<i32>>)> {
    std::thread::spawn(move || {
        let mut rng = Pcg64::new(0xBEEF);
        let mut latencies = Summary::new();
        let mut done = Vec::new();
        for _ in 0..n_requests {
            let i = rng.below(corpus.n);
            let (rtx, rrx) = mpsc::channel();
            let t_submit = Instant::now();
            tx.send(Request {
                tokens: corpus.src_row(i).to_vec(),
                t_arrival: t_submit,
                respond: rtx,
            })
            .ok();
            // Latency is measured at receive time, so it includes the
            // response channel hop the server-side percentile rows can't
            // see.
            if let Ok((toks, _lat)) = rrx.recv() {
                latencies.add(t_submit.elapsed().as_secs_f64());
                done.push(toks);
            }
        }
        (latencies, done)
    })
}

fn print_demo_stats(
    label: &str,
    kind: &str,
    batcher: Batcher,
    capacity: usize,
    stats: &ServeStats,
    latencies: &Summary,
    translations: &[Vec<i32>],
) {
    println!(
        "== serving demo ({label}, backend {kind}, {} batcher, capacity {capacity}) ==",
        batcher.key()
    );
    let unit = match batcher {
        Batcher::Static => "batches",
        Batcher::Continuous => "decode steps",
    };
    println!("requests      : {} ({} {unit})", stats.served, stats.batches);
    println!("wall time     : {:.2}s", stats.wall_s);
    println!("throughput    : {:.1} sentences/s", stats.served as f64 / stats.wall_s);
    println!(
        "tokens/sec    : {:.1} ({} generated tokens)",
        stats.tokens_per_s(),
        stats.tokens
    );
    println!("occupancy     : {:.1}% of capacity per {unit}", stats.occupancy * 100.0);
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3} (client-observed)",
        latencies.quantile(0.5),
        latencies.quantile(0.95),
        latencies.max()
    );
    println!(
        "latency (s)   : p50 {:.3}  p95 {:.3}  max {:.3} (server-side, n={})",
        stats.latency.quantile(0.5),
        stats.latency.quantile(0.95),
        stats.latency.max(),
        stats.latency.count()
    );
    println!(
        "sample output : {:?}",
        translations.first().map(|t| &t[..t.len().min(8)])
    );
}

/// Closed-loop demo driver over the **static** batcher: a client thread
/// submits `n_requests` random test sentences back-to-back,
/// [`serve_loop`] batches and executes them, and the latency/throughput
/// summary is printed.
pub fn run_demo(
    backend: &dyn TranslateBackend,
    corpus: Corpus,
    dims: &ModelDims,
    n_requests: usize,
    label: &str,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();
    let client = spawn_client(corpus, n_requests, tx);
    let stats = serve_loop(backend, &rx, dims, n_requests)?;
    let (latencies, translations) = client.join().expect("client thread");
    print_demo_stats(
        label,
        backend.kind(),
        Batcher::Static,
        backend.batch(),
        &stats,
        &latencies,
        &translations,
    );
    Ok(stats)
}

/// [`run_demo`]'s twin over the **continuous** batcher: same closed-loop
/// client, served by [`serve_loop_continuous`] at `capacity` slots.
pub fn run_demo_continuous<E: SlotEngine>(
    engine: &E,
    kind: &str,
    capacity: usize,
    corpus: Corpus,
    dims: &ModelDims,
    n_requests: usize,
    label: &str,
) -> Result<ServeStats> {
    let (tx, rx) = mpsc::channel::<Request>();
    let client = spawn_client(corpus, n_requests, tx);
    let stats = serve_loop_continuous(engine, &rx, dims, n_requests, capacity)?;
    let (latencies, translations) = client.join().expect("client thread");
    print_demo_stats(
        label,
        kind,
        Batcher::Continuous,
        capacity,
        &stats,
        &latencies,
        &translations,
    );
    Ok(stats)
}

/// Serving demo on the native runtime: W8A8-quantized model (the
/// deployment configuration), no PJRT anywhere. Works in every build.
///
/// `mode` picks the execution form of the quantized weights:
/// `Mode::Dense` serves fake-quant f32, `Mode::Quantized` serves the
/// bit-packed bank (same tokens bit for bit, ~4x fewer weight bytes
/// resident at W8). `decode` picks the greedy-decode loop — KV-cached
/// single-token steps (the serving default) or the full-buffer replay
/// reference; both produce identical tokens, the cached loop just
/// serves them a `seq_len`-factor cheaper. `batcher` picks the serving
/// discipline — static group-decode-respond waves, or the continuous
/// slot scheduler (requires the cached decode policy; identical tokens
/// either way, the batch just stays full under dynamic load).
pub fn serve_demo_native(
    manifest: &crate::model::Manifest,
    pair: &str,
    n_requests: usize,
    workers: usize,
    mode: Mode,
    decode: DecodePolicy,
    batcher: Batcher,
) -> Result<ServeStats> {
    let info = manifest
        .pairs
        .get(pair)
        .ok_or_else(|| anyhow::anyhow!("unknown language pair {pair}"))?;
    let corpus = Corpus::load(&info.corpus)?;
    let model = crate::model::PairModel::load(manifest, pair)?;
    let weights: Vec<&crate::tensor::Matrix> =
        manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = super::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm.native_backend_mode(manifest, &model, mode, workers)?.with_decode(decode);
    let label = format!(
        "{pair}, W8A8, {} exec, {} decode, {} batcher",
        mode.key(),
        decode.key(),
        batcher.key()
    );
    match batcher {
        Batcher::Static => run_demo(&backend, corpus, &manifest.model, n_requests, &label),
        Batcher::Continuous => {
            anyhow::ensure!(
                decode == DecodePolicy::Cached,
                "the continuous batcher schedules KV slots; it requires --decode cached \
                 (replay has no slot lifecycle to interleave)"
            );
            let capacity = backend.batch();
            run_demo_continuous(
                &backend,
                "native",
                capacity,
                corpus,
                &manifest.model,
                n_requests,
                &label,
            )
        }
    }
}

/// Serving demo over the PJRT runtime (kept for artifact parity runs).
#[cfg(feature = "pjrt")]
pub fn serve_demo(c: &Coordinator, pair: &str, n_requests: usize) -> Result<ServeStats> {
    let corpus = Corpus::load(&c.manifest.pairs[pair].corpus)?;
    let session = TranslateSession::new(&c.engine, &c.manifest, Mode::Dense)?;
    // Serve the W8A8 quantized model — the deployment configuration.
    let cm = c.compress(pair, &Method::QuantOnly { wl: 8 });
    let bank = session.build_bank(c.model(pair), &cm.layers, cm.act_wl)?;
    let backend = PjrtBackend::new(session, bank);
    run_demo(&backend, corpus, &c.manifest.model, n_requests, &format!("{pair}, W8A8"))
}

/// Compressed-model variants available to the serving example.
#[cfg(feature = "pjrt")]
pub fn serve_bank<'a>(
    c: &'a Coordinator,
    session: &TranslateSession,
    pair: &str,
    method: &Method,
) -> Result<crate::runtime::ArgBank> {
    let cm = c.compress(pair, method);
    session.build_bank(c.model(pair), &cm.layers, cm.act_wl)
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::cell::Cell;

    /// Echo backend: "translates" by returning the source buffer and
    /// records the size of the last call for shape assertions.
    struct Echo {
        batch: usize,
        seq: usize,
        fixed: bool,
        last_len: Cell<usize>,
    }

    impl Echo {
        fn new(batch: usize, seq: usize, fixed: bool) -> Echo {
            Echo { batch, seq, fixed, last_len: Cell::new(0) }
        }
    }

    impl TranslateBackend for Echo {
        fn kind(&self) -> &'static str {
            "echo"
        }
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn fixed_shape(&self) -> bool {
            self.fixed
        }
        fn translate(&self, src_tokens: &[i32]) -> Result<Vec<i32>> {
            if self.fixed {
                assert_eq!(src_tokens.len(), self.batch * self.seq, "fixed-shape call");
            } else {
                assert!(
                    !src_tokens.is_empty() && src_tokens.len() % self.seq == 0,
                    "variable-shape call must still be row-aligned"
                );
            }
            self.last_len.set(src_tokens.len());
            Ok(src_tokens.to_vec())
        }
    }

    fn dims(seq_len: usize, eval_batch: usize) -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            n_enc: 1,
            n_dec: 1,
            seq_len,
            eval_batch,
            pad_id: 0,
            bos_id: 1,
            eos_id: 2,
        }
    }

    #[test]
    fn pack_rows_pads_and_truncates() {
        let rows: Vec<&[i32]> = vec![&[1, 5, 6, 2], &[1, 9, 2, 7, 7, 7]];
        let src = pack_rows(&rows, 3, 5, 0);
        assert_eq!(src.len(), 15);
        assert_eq!(&src[..5], &[1, 5, 6, 2, 0]); // padded
        assert_eq!(&src[5..10], &[1, 9, 2, 7, 7]); // truncated at seq
        assert_eq!(&src[10..], &[0; 5]); // empty slot stays PAD
    }

    #[test]
    #[should_panic(expected = "exceed batch capacity")]
    fn pack_rows_rejects_overfull() {
        let rows: Vec<&[i32]> = vec![&[1], &[2], &[3]];
        pack_rows(&rows, 2, 4, 0);
    }

    #[test]
    fn serve_loop_batches_and_responds() {
        let backend = Echo::new(4, 6, true);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // Queue 5 requests up-front: expect one full batch + one single.
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                tokens: vec![1, 10 + i, 2],
                t_arrival: Instant::now(),
                respond: rtx,
            })
            .unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 5).unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.batches, 2, "4-capacity batcher must split 5 into 4+1");
        assert_eq!(stats.tokens, 5, "one de-framed token per echoed request");
        assert_eq!(stats.latency.count(), 5, "one server-side latency sample per request");
        assert!(stats.tokens_per_s() > 0.0);
        for (i, rrx) in receivers.into_iter().enumerate() {
            let (toks, lat) = rrx.recv().unwrap();
            // Echo + strip_specials leaves exactly the content token.
            assert_eq!(toks, vec![10 + i as i32]);
            assert!(lat >= 0.0);
        }
    }

    /// Head-of-line regression: with fewer queued requests than batch
    /// capacity and the sender still alive, the loop must flush a
    /// partial batch instead of waiting indefinitely for a full one.
    /// (If `next_batch` ever regresses to blocking until `capacity`
    /// requests arrive, this test hangs: the sender is never dropped.)
    #[test]
    fn partial_batch_flushes_without_disconnect() {
        let backend = Echo::new(4, 6, true);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for i in 0..2 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                tokens: vec![1, 20 + i, 2],
                t_arrival: Instant::now(),
                respond: rtx,
            })
            .unwrap();
            receivers.push(rrx);
        }
        // NOTE: tx intentionally kept alive — no disconnect to fall back on.
        let stats = serve_loop(&backend, &rx, &d, 2).unwrap();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.received, 2, "requests in == responses out");
        assert_eq!(stats.batches, 1, "both queued requests flush in one partial batch");
        assert!((stats.occupancy - 0.5).abs() < 1e-12, "2 of 4 slots occupied");
        for (i, rrx) in receivers.into_iter().enumerate() {
            let (toks, _) = rrx.recv().unwrap();
            assert_eq!(toks, vec![20 + i as i32]);
        }
        drop(tx);
    }

    /// Minimal slot engine for continuous-loop unit tests: admission
    /// stores the framed row, one step completes it, output echoes it.
    struct EchoSlots {
        seq: usize,
    }

    struct EchoSlot {
        row: Vec<i32>,
        stepped: bool,
    }

    impl crate::runtime::SlotEngine for EchoSlots {
        type Slot = EchoSlot;
        fn slot_seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&self, src_row: &[i32]) -> Result<EchoSlot> {
            assert_eq!(src_row.len(), self.seq, "framed admission");
            Ok(EchoSlot { row: src_row.to_vec(), stepped: false })
        }
        fn step(&self, slots: &mut [&mut EchoSlot]) -> Result<()> {
            for s in slots.iter_mut() {
                s.stepped = true;
            }
            Ok(())
        }
        fn slot_complete(&self, slot: &EchoSlot) -> bool {
            slot.stepped
        }
        fn slot_output(&self, slot: &EchoSlot) -> Vec<i32> {
            slot.row.clone()
        }
    }

    #[test]
    fn continuous_loop_serves_and_balances() {
        let engine = EchoSlots { seq: 6 };
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        let mut receivers = Vec::new();
        for i in 0..5 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                tokens: vec![1, 30 + i, 2],
                t_arrival: Instant::now(),
                respond: rtx,
            })
            .unwrap();
            receivers.push(rrx);
        }
        drop(tx);
        let stats = serve_loop_continuous(&engine, &rx, &d, 5, 3).unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.received, 5, "requests in == responses out");
        assert!(stats.batches >= 2, "5 one-step requests need >= 2 decode steps at capacity 3");
        assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0);
        assert_eq!(stats.tokens, 5, "one de-framed token per echoed request");
        assert_eq!(stats.latency.count(), 5);
        for (i, rrx) in receivers.into_iter().enumerate() {
            let (toks, lat) = rrx.recv().unwrap();
            assert_eq!(toks, vec![30 + i as i32], "responses route to their requester, FIFO");
            assert!(lat >= 0.0 && lat.is_finite());
        }
    }

    #[test]
    fn serve_loop_stops_on_disconnect() {
        let backend = Echo::new(2, 4, true);
        let d = dims(4, 2);
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 10).unwrap();
        assert_eq!(stats.served, 0);
        assert_eq!(stats.batches, 0);
        assert_eq!(stats.tokens, 0);
        assert_eq!(stats.latency.count(), 0);
    }

    #[test]
    fn serve_loop_packs_partial_batches_for_variable_shape_backends() {
        let backend = Echo::new(4, 6, false);
        let d = dims(6, 4);
        let (tx, rx) = mpsc::channel::<Request>();
        // A single queued request: the variable-shape path must translate
        // exactly one row (Echo asserts the buffer never exceeds what was
        // packed; a full-capacity pad would be 4 rows).
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            tokens: vec![1, 42, 2],
            t_arrival: Instant::now(),
            respond: rtx,
        })
        .unwrap();
        drop(tx);
        let stats = serve_loop(&backend, &rx, &d, 1).unwrap();
        assert_eq!(stats.served, 1);
        assert_eq!(backend.last_len.get(), 6, "one row packed, not the full capacity");
        let (toks, _) = rrx.recv().unwrap();
        assert_eq!(toks, vec![42]);
    }
}
