"""L2 model tests: shapes, kernel/jnp path equivalence, training signal,
greedy decode behaviour, calibration stats."""

import numpy as np
import jax
import pytest

from compile import data as D
from compile import model as M

SMALL = M.ModelConfig(d_model=32, n_heads=4, d_ff=64, n_enc=1, n_dec=1)


def scales(cfg):
    return np.ones(len(M.compressed_linear_names(cfg)), np.float32)


@pytest.fixture(scope="module")
def small_setup():
    params = M.init_params(SMALL, seed=3)
    corpus = D.make_corpus("en-de", 8, seed=11)
    return params, corpus


def test_param_inventory_consistency():
    names = M.compressed_linear_names(SMALL)
    assert len(names) == SMALL.n_enc * 6 + SMALL.n_dec * 10
    dense = M.param_specs("dense", SMALL)
    svd = M.param_specs("svd", SMALL)
    # svd replaces each linear with two factors.
    assert len(svd) == len(dense) + len(names)
    for n in names:
        k, nn = M.linear_shape(n, SMALL)
        assert M.r_max(n, SMALL) == min(k, nn)


def test_forward_logits_shape(small_setup):
    params, corpus = small_setup
    lg = M.forward_logits(params, corpus.src, corpus.tgt, scales(SMALL), 0.0,
                          cfg=SMALL, use_kernels=False)
    assert lg.shape == (8, SMALL.seq_len, SMALL.vocab)
    assert np.all(np.isfinite(np.asarray(lg)))


def test_kernel_and_jnp_paths_agree(small_setup):
    """The Pallas-kernel path and the pure-jnp training path must be the
    same function — this ties L1 kernels to the artifacts' semantics."""
    params, corpus = small_setup
    src, tgt = corpus.src[:2], corpus.tgt[:2]
    a = M.forward_logits(params, src, tgt, scales(SMALL), 0.0, cfg=SMALL,
                         use_kernels=True)
    b = M.forward_logits(params, src, tgt, scales(SMALL), 0.0, cfg=SMALL,
                         use_kernels=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)


def test_act_quant_changes_output_and_levels0_does_not(small_setup):
    params, corpus = small_setup
    src, tgt = corpus.src[:2], corpus.tgt[:2]
    base = M.forward_logits(params, src, tgt, scales(SMALL), 0.0, cfg=SMALL,
                            use_kernels=False)
    coarse = M.forward_logits(params, src, tgt, scales(SMALL) * 0.5, 3.0,
                              cfg=SMALL, use_kernels=False)
    assert not np.allclose(np.asarray(base), np.asarray(coarse))


def test_translate_is_bos_framed_and_int(small_setup):
    params, corpus = small_setup
    out = np.asarray(
        M.translate(params, corpus.src, scales(SMALL), 0.0, cfg=SMALL,
                    use_kernels=False)
    )
    assert out.shape == corpus.src.shape
    assert out.dtype == np.int32
    assert np.all(out[:, 0] == D.BOS_ID)
    assert np.all((out >= 0) & (out < SMALL.vocab))


def test_collect_stats_returns_positive_maxabs(small_setup):
    params, corpus = small_setup
    _, stats = M.forward_logits(params, corpus.src, corpus.tgt, scales(SMALL),
                                0.0, cfg=SMALL, collect_stats=True,
                                use_kernels=False)
    stats = np.asarray(stats)
    assert stats.shape == (len(M.compressed_linear_names(SMALL)),)
    assert np.all(stats > 0)


def test_loss_decreases_quickly():
    """A handful of Adam steps on the tiny config must reduce the loss —
    the smoke version of the build-time training run."""
    from compile import train as T

    cfg = SMALL
    corpus = D.make_corpus("en-de", 64, seed=5)
    params = M.init_params(cfg, seed=0)
    sc = scales(cfg)
    loss_grad = jax.jit(
        jax.value_and_grad(lambda p, s, t: T._loss_fn(p, s, t, sc, cfg))
    )
    l0, _ = loss_grad(params, corpus.src[:16], corpus.tgt[:16])
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(p) for k, p in params.items()}
    for step in range(1, 31):
        loss, grads = loss_grad(params, corpus.src[:16], corpus.tgt[:16])
        for k in params:
            g = np.asarray(grads[k])
            m[k] = 0.9 * m[k] + 0.1 * g
            v[k] = 0.999 * v[k] + 0.001 * g * g
            mh = m[k] / (1 - 0.9**step)
            vh = v[k] / (1 - 0.999**step)
            params[k] = params[k] - 5e-3 * mh / (np.sqrt(vh) + 1e-8)
    l1, _ = loss_grad(params, corpus.src[:16], corpus.tgt[:16])
    assert float(l1) < float(l0) * 0.8, f"{float(l0)} -> {float(l1)}"
