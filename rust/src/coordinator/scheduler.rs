//! Dynamic admission scheduling for slot-addressed decode: the
//! continuous-batching engine.
//!
//! The static batcher ([`super::serve::serve_loop`]) runs one monolithic
//! batch lifecycle: group requests, decode the whole batch to completion
//! (stragglers pin every other row), respond, repeat — so the decode
//! engine idles between waves. [`ContinuousBatcher`] keeps it hot by
//! scheduling per-sequence KV slots ([`crate::runtime::SlotEngine`])
//! instead of batches: **between decode steps** it retires EOS'd slots,
//! admits queued requests into the freed capacity (running their encoder
//! pass and splicing their cross-attention context into the live batch),
//! and steps the resulting mixed-age batch.
//!
//! Scheduling is deterministic and wall-clock-free — admission is FIFO
//! into the lowest free slot index, slots are never preempted (a long
//! request keeps its slot until it completes, so nothing starves), and
//! an idle tick (no live slots, empty queue) is a no-op. That makes the
//! policy unit-testable with scripted arrival/length traces against a
//! mock engine, with no model anywhere.
//!
//! Outputs are **bit-identical** to decoding each request alone through
//! the cached path: slot independence is the engine's contract
//! ([`crate::runtime::SlotEngine`]), pinned end-to-end by
//! `prop_continuous_decode_bit_identical_to_sequential`, the serving
//! soak test and `itera validate --batcher continuous`.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::runtime::SlotEngine;

/// Which serving batcher runs the decode loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Batcher {
    /// Monolithic batch lifecycle: fill up to capacity, decode the whole
    /// batch to completion, respond, repeat.
    #[default]
    Static,
    /// Slot-addressed lifecycle: retire/admit between decode steps so
    /// the batch stays full under dynamic load ([`ContinuousBatcher`]).
    Continuous,
}

impl Batcher {
    pub fn key(self) -> &'static str {
        match self {
            Batcher::Static => "static",
            Batcher::Continuous => "continuous",
        }
    }

    /// Parse a CLI `--batcher` value.
    pub fn parse(s: &str) -> Option<Batcher> {
        match s {
            "static" => Some(Batcher::Static),
            "continuous" => Some(Batcher::Continuous),
            _ => None,
        }
    }
}

/// One finished request, reported by [`ContinuousBatcher::tick`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Completion {
    /// Submission id (assigned FIFO by [`ContinuousBatcher::submit`]).
    pub id: u64,
    /// Slot index the request decoded in (observable slot reuse).
    pub slot: usize,
    /// The decoded `seq_len`-token output buffer.
    pub tokens: Vec<i32>,
}

/// Deterministic scheduling counters.
#[derive(Debug, Clone, Default)]
pub struct BatcherStats {
    /// Decode steps executed (idle ticks are not steps).
    pub steps: usize,
    /// Requests admitted into a slot.
    pub admitted: usize,
    /// Slots retired (EOS or full buffer).
    pub retired: usize,
    /// Sum over steps of live slots — the occupancy numerator.
    pub occupied_slot_steps: usize,
}

impl BatcherStats {
    /// Mean fraction of `capacity` occupied per decode step, in `[0, 1]`.
    pub fn occupancy(&self, capacity: usize) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.occupied_slot_steps as f64 / (self.steps * capacity.max(1)) as f64
    }
}

struct Live<S> {
    id: u64,
    slot: S,
}

/// Continuous-batching engine over any [`SlotEngine`].
///
/// `capacity` bounds concurrent slots; requests beyond it queue FIFO.
/// Drive it with [`submit`](Self::submit) + [`tick`](Self::tick) (one
/// retire/admit/step round per call) or [`run_until_drained`]
/// (Self::run_until_drained).
pub struct ContinuousBatcher<'e, E: SlotEngine> {
    engine: &'e E,
    capacity: usize,
    /// Fixed-capacity slot table; `None` entries are free and reusable.
    slots: Vec<Option<Live<E::Slot>>>,
    /// FIFO admission queue of `(id, framed source row)`.
    queue: VecDeque<(u64, Vec<i32>)>,
    next_id: u64,
    stats: BatcherStats,
}

impl<'e, E: SlotEngine> ContinuousBatcher<'e, E> {
    pub fn new(engine: &'e E, capacity: usize) -> ContinuousBatcher<'e, E> {
        assert!(capacity >= 1, "continuous batcher needs at least one slot");
        ContinuousBatcher {
            engine,
            capacity,
            slots: (0..capacity).map(|_| None).collect(),
            queue: VecDeque::new(),
            next_id: 0,
            stats: BatcherStats::default(),
        }
    }

    /// Enqueue one `seq_len`-framed request; returns its id (ids are
    /// assigned — and admitted — in submission order).
    pub fn submit(&mut self, src_row: Vec<i32>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, src_row));
        id
    }

    /// Requests waiting for a slot.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Currently occupied slots.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Slot capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Nothing live and nothing queued: a [`tick`](Self::tick) would be
    /// a no-op.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn stats(&self) -> &BatcherStats {
        &self.stats
    }

    /// Mean slot occupancy over all decode steps so far.
    pub fn occupancy(&self) -> f64 {
        self.stats.occupancy(self.capacity)
    }

    /// One scheduling round: admit queued requests into free slots
    /// (FIFO, lowest free index first — each admission runs the
    /// request's encoder pass), retire anything already complete (a
    /// degenerate admission can be born finished — it must never reach
    /// the step kernel), step the mixed-age batch of live slots once,
    /// then retire completed slots and return every output. An idle
    /// round (nothing live after admission) executes no decode step.
    pub fn tick(&mut self) -> Result<Vec<Completion>> {
        // Admit: fill every free slot while the queue has work.
        for entry in self.slots.iter_mut() {
            if entry.is_some() {
                continue;
            }
            let Some((id, row)) = self.queue.pop_front() else { break };
            ensure!(
                row.len() == self.engine.slot_seq_len(),
                "request {id}: {} tokens, slots are {}-framed",
                row.len(),
                self.engine.slot_seq_len()
            );
            *entry = Some(Live { id, slot: self.engine.admit(&row)? });
            self.stats.admitted += 1;
        }

        // Pre-step retire: only admissions that are complete on arrival
        // (e.g. a seq_len-1 buffer, or EOS aliased to BOS/PAD) — slots
        // finished by a step were retired at the end of that tick.
        let mut done = self.retire_complete();

        // Step whatever is live, in ascending slot order (slot
        // independence makes the order bit-irrelevant; fixing it keeps
        // traces reproducible).
        let mut live: Vec<&mut E::Slot> =
            self.slots.iter_mut().filter_map(|e| e.as_mut().map(|l| &mut l.slot)).collect();
        if live.is_empty() {
            return Ok(done);
        }
        let occupied = live.len();
        self.engine.step(&mut live)?;
        self.stats.steps += 1;
        self.stats.occupied_slot_steps += occupied;

        // Retire: free completed slots for the next tick's admissions.
        done.extend(self.retire_complete());
        Ok(done)
    }

    /// Take every complete slot out of the table (freeing it for reuse)
    /// and return the completions in ascending slot order.
    fn retire_complete(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        for (si, entry) in self.slots.iter_mut().enumerate() {
            let complete = match entry {
                Some(l) => self.engine.slot_complete(&l.slot),
                None => false,
            };
            if complete {
                let l = entry.take().expect("checked Some above");
                done.push(Completion {
                    id: l.id,
                    slot: si,
                    tokens: self.engine.slot_output(&l.slot),
                });
                self.stats.retired += 1;
            }
        }
        done
    }

    /// Tick until nothing is live or queued; returns every completion in
    /// retirement order.
    pub fn run_until_drained(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            out.extend(self.tick()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scripted mock engine: no model, no clock. A request row encodes
    /// its own lifecycle — `row[0]` is the number of decode steps until
    /// EOS, `row[1]` a tag echoed in the output — so arrival/length
    /// traces are fully deterministic.
    struct ScriptEngine {
        seq: usize,
    }

    struct ScriptSlot {
        need: usize,
        len: usize,
        tag: i32,
    }

    impl SlotEngine for ScriptEngine {
        type Slot = ScriptSlot;

        fn slot_seq_len(&self) -> usize {
            self.seq
        }

        fn admit(&self, src_row: &[i32]) -> Result<ScriptSlot> {
            ensure!(src_row.len() == self.seq, "framing");
            Ok(ScriptSlot { need: src_row[0] as usize, len: 0, tag: src_row[1] })
        }

        fn step(&self, slots: &mut [&mut ScriptSlot]) -> Result<()> {
            for s in slots.iter_mut() {
                s.len += 1;
            }
            Ok(())
        }

        fn slot_complete(&self, s: &ScriptSlot) -> bool {
            s.len >= s.need || s.len + 1 >= self.seq
        }

        fn slot_output(&self, s: &ScriptSlot) -> Vec<i32> {
            vec![s.tag, s.len as i32]
        }
    }

    fn req(need: usize, tag: i32, seq: usize) -> Vec<i32> {
        let mut r = vec![0; seq];
        r[0] = need as i32;
        r[1] = tag;
        r
    }

    #[test]
    fn fifo_admission_and_capacity_never_exceeded() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2);
        for i in 0..5 {
            b.submit(req(3, i, 16));
        }
        assert_eq!(b.pending(), 5);
        let mut completions = Vec::new();
        for _ in 0..30 {
            assert!(b.live() <= 2, "live slots exceed capacity");
            completions.extend(b.tick().unwrap());
            assert!(b.live() <= 2, "live slots exceed capacity after tick");
            if b.idle() {
                break;
            }
        }
        assert!(b.idle(), "trace must drain");
        // Equal-length requests: FIFO admission implies FIFO completion.
        let ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "FIFO admission order");
        assert_eq!(b.stats().admitted, 5);
        assert_eq!(b.stats().retired, 5);
    }

    #[test]
    fn slot_reuse_after_retirement() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        // Slot 0 retires first (1 step), slots 1/2 run long.
        b.submit(req(1, 10, 16));
        b.submit(req(6, 11, 16));
        b.submit(req(6, 12, 16));
        let first = b.tick().unwrap();
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].id, 0);
        assert_eq!(first[0].slot, 0, "short request lived in slot 0");
        // The next request must land in the freed slot 0, not a new one.
        b.submit(req(1, 13, 16));
        let second = b.tick().unwrap();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].id, 3);
        assert_eq!(second[0].slot, 0, "retired slot is reused");
        assert_eq!(b.live(), 2, "long requests still hold slots 1 and 2");
    }

    #[test]
    fn long_requests_are_never_starved() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2);
        let long_id = b.submit(req(6, 99, 16));
        // A stream of short requests arrives every tick; the long request
        // keeps its slot (no preemption) and completes on schedule.
        let mut long_done_at = None;
        for tick in 1..=10 {
            b.submit(req(1, tick, 16));
            for c in b.tick().unwrap() {
                if c.id == long_id {
                    long_done_at = Some(tick);
                }
            }
        }
        assert_eq!(long_done_at, Some(6), "6-step request completes at tick 6");
    }

    #[test]
    fn empty_queue_idle_tick_is_a_noop() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 4);
        assert!(b.idle());
        assert_eq!(b.tick().unwrap(), Vec::new());
        assert_eq!(b.stats().steps, 0, "idle tick executes no decode step");
        assert_eq!(b.occupancy(), 0.0);
        // ... and the batcher still works after idling.
        b.submit(req(2, 7, 16));
        assert!(!b.idle());
        let out = b.run_until_drained().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, vec![7, 2]);
        assert_eq!(b.stats().steps, 2);
    }

    #[test]
    fn backlogged_trace_keeps_slots_occupied() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        for i in 0..9 {
            b.submit(req(4, i, 16));
        }
        let out = b.run_until_drained().unwrap();
        assert_eq!(out.len(), 9);
        // Equal 4-step lifecycles in cohorts of 3: every step runs a full
        // batch, so occupancy is exactly 1.
        assert_eq!(b.stats().steps, 12);
        assert!((b.occupancy() - 1.0).abs() < 1e-12, "occupancy {}", b.occupancy());
    }

    #[test]
    fn staggered_arrivals_mix_slot_ages() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 3);
        // Arrivals staggered across ticks; lengths differ, so admissions
        // backfill mid-decode and the batch holds mixed-age slots.
        b.submit(req(2, 0, 16));
        b.submit(req(5, 1, 16));
        let mut completions = Vec::new();
        for t in 0..12 {
            if t == 1 {
                b.submit(req(2, 2, 16));
            }
            if t == 3 {
                b.submit(req(1, 3, 16));
            }
            completions.extend(b.tick().unwrap());
            if b.idle() {
                break;
            }
        }
        assert_eq!(completions.len(), 4);
        // The long request (id 1) outlives later arrivals: 2 and 3
        // complete before it — continuous batching, not head-of-line.
        let pos = |id: u64| completions.iter().position(|c| c.id == id).unwrap();
        assert!(pos(2) < pos(1) && pos(3) < pos(1), "later short requests finish first");
        assert_eq!(b.stats().admitted, 4);
        assert_eq!(b.stats().retired, 4);
        assert!(b.occupancy() > 0.5, "occupancy {}", b.occupancy());
    }

    #[test]
    fn born_complete_admissions_retire_without_stepping() {
        // A slot that is complete the moment it is admitted (need = 0 —
        // the mock twin of a seq_len-1 buffer or EOS-aliased framing)
        // must be retired before the step batch forms, never stepped.
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 2);
        b.submit(req(0, 41, 16));
        let out = b.tick().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, vec![41, 0], "retired at age 0: never stepped");
        assert_eq!(b.stats().steps, 0, "no live work, no decode step");
        assert!(b.idle());
        // Mixed with a real request, the degenerate one still skips the
        // step batch while the live one decodes normally.
        b.submit(req(0, 42, 16));
        b.submit(req(2, 43, 16));
        let first = b.tick().unwrap();
        assert_eq!(first.len(), 1, "only the born-complete request retires this tick");
        assert_eq!(first[0].tokens, vec![42, 0]);
        let rest = b.run_until_drained().unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].tokens, vec![43, 2], "the live request stepped to completion");
    }

    #[test]
    fn rejects_misframed_requests() {
        let e = ScriptEngine { seq: 16 };
        let mut b = ContinuousBatcher::new(&e, 1);
        b.submit(vec![1, 2, 3]); // not seq_len-framed
        assert!(b.tick().is_err(), "misframed request must fail admission");
    }

    #[test]
    fn batcher_keys_parse() {
        for k in [Batcher::Static, Batcher::Continuous] {
            assert_eq!(Batcher::parse(k.key()), Some(k));
        }
        assert_eq!(Batcher::default(), Batcher::Static);
        assert_eq!(Batcher::parse("vllm"), None);
    }
}
