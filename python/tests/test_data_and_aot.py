"""Corpus generator determinism/grammar tests + AOT lowering round-trip."""

import numpy as np
import pytest

from compile import data as D


def test_corpus_deterministic():
    a = D.make_corpus("en-de", 16, seed=3)
    b = D.make_corpus("en-de", 16, seed=3)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.tgt, b.tgt)
    c = D.make_corpus("en-de", 16, seed=4)
    assert not np.array_equal(a.src, c.src)


def test_corpus_framing_and_vocab():
    c = D.make_corpus("fr-en", 32, seed=1)
    for row in np.concatenate([c.src, c.tgt]):
        assert row[0] == D.BOS_ID
        content = row[1:]
        # exactly one EOS before padding
        eos_pos = np.where(content == D.EOS_ID)[0]
        assert len(eos_pos) == 1
        assert np.all(content[eos_pos[0] + 1:] == D.PAD_ID)
        assert np.all(row < D.VOCAB_SIZE)
        assert np.all(row >= 0)


def test_en_de_rules_verb_final_and_agreement():
    table = D._dictionary("en-de")
    # DET ADJ NOUN VERB clause: target must be det' adj' noun' SUF verb'.
    toks = [D.DET0, D.ADJ0 + 1, D.NOUN0 + 2, D.VERB0 + 3]
    out = D.translate_en_de(toks, table)
    assert out[0] == int(table[D.DET0])
    assert out[1] == int(table[D.ADJ0 + 1])
    assert out[2] == int(table[D.NOUN0 + 2])
    assert D.SUF0 <= out[3] < D.SUF0 + D.N_SUFFIX  # agreement suffix
    assert out[4] == int(table[D.VERB0 + 3])  # verb moved to clause end


def test_fr_en_rules_swap_and_det_drop():
    table = D._dictionary("fr-en")
    toks = [D.DET0 + 2, D.ADJ0, D.NOUN0, D.VERB0]
    out = D.translate_fr_en(toks, table)
    # determiner dropped; (adj, noun) swapped; verb remapped in place.
    assert out[0] == int(table[D.NOUN0])
    assert out[1] == int(table[D.ADJ0])
    assert out[2] == int(table[D.VERB0])


def test_dictionaries_differ_between_pairs_and_are_bijective():
    a = D._dictionary("en-de")
    b = D._dictionary("fr-en")
    assert not np.array_equal(a, b)
    for t in (a, b):
        assert sorted(t.tolist()) == list(range(D.VOCAB_SIZE))


@pytest.mark.slow
def test_aot_lowering_roundtrip(tmp_path):
    """Lower the tiny-config translate fn to HLO text; it must be
    non-trivial and contain no custom-calls (CPU-executable)."""
    import jax
    import jax.numpy as jnp

    from compile import aot, model as M

    cfg = M.ModelConfig(d_model=32, n_heads=4, d_ff=64, n_enc=1, n_dec=1)
    text = aot.lower_translate("dense", cfg, batch=2)
    assert len(text) > 10_000
    assert "custom-call" not in text.lower()
    assert "ENTRY" in text

    text_svd = aot.lower_translate("svd", cfg, batch=2)
    assert len(text_svd) > 10_000

    # And the microbench artifact.
    micro = aot.lower_linear512("dense")
    assert "ENTRY" in micro
