//! Numerical linear algebra substrate.
//!
//! The paper's Algorithm 1 needs, per iteration, only the **leading**
//! singular triplet of the current residual — `svd_top1` (alternating power
//! iteration) provides that at O(sweeps · m · n) instead of a full
//! decomposition, and is the compression engine's hot path. The full
//! one-sided Jacobi SVD (`svd`) backs the plain-SVD baseline (§VIII-B),
//! rank-sweep experiments, and cross-validates `svd_top1` in tests.

mod jacobi;
mod power;

pub use jacobi::{svd, Svd};
pub use power::{svd_top1, svd_top1_ws, PowerWorkspace, TopTriplet};

use crate::tensor::Matrix;

/// Reconstruct `U[:, :r] * diag(S[:r]) * Vt[:r, :]`.
pub fn reconstruct(svd: &Svd, r: usize) -> Matrix {
    let r = r.min(svd.s.len());
    let mut out = Matrix::zeros(svd.u.rows(), svd.vt.cols());
    for k in 0..r {
        let sk = svd.s[k];
        let uk = svd.u.col(k);
        let vk = svd.vt.row(k);
        for i in 0..out.rows() {
            let c = sk * uk[i];
            if c == 0.0 {
                continue;
            }
            let row = out.row_mut(i);
            for (o, &v) in row.iter_mut().zip(vk) {
                *o += c * v;
            }
        }
    }
    out
}

/// Split a rank-r truncation into the paper's Eq. 2 factors:
/// `W1 = U_r * S_r^{1/2}` (K x r), `W2 = S_r^{1/2} * V_r^T` (r x N).
pub fn factor_pair(svd: &Svd, r: usize) -> (Matrix, Matrix) {
    let r = r.min(svd.s.len());
    let w1 = Matrix::from_fn(svd.u.rows(), r, |i, k| svd.u.get(i, k) * svd.s[k].max(0.0).sqrt());
    let w2 = Matrix::from_fn(r, svd.vt.cols(), |k, j| svd.s[k].max(0.0).sqrt() * svd.vt.get(k, j));
    (w1, w2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn reconstruct_full_rank_recovers() {
        let mut rng = Pcg64::new(10);
        let a = Matrix::randn(8, 6, &mut rng);
        let d = svd(&a);
        let r = reconstruct(&d, 6);
        assert!(r.sub(&a).frob_norm() < 1e-3 * a.frob_norm().max(1.0));
    }

    #[test]
    fn factor_pair_product_matches_reconstruct() {
        let mut rng = Pcg64::new(11);
        let a = Matrix::randn(10, 7, &mut rng);
        let d = svd(&a);
        for r in [1, 3, 7] {
            let (w1, w2) = factor_pair(&d, r);
            assert_eq!(w1.shape(), (10, r));
            assert_eq!(w2.shape(), (r, 7));
            let prod = w1.matmul(&w2);
            let rec = reconstruct(&d, r);
            assert!(prod.sub(&rec).frob_norm() < 1e-4 * rec.frob_norm().max(1.0));
        }
    }
}
