//! `itera` — CLI entry point for the ITERA-LLM co-design framework.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = itera_llm::cli::main_with_args(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
