//! Serving telemetry: a dependency-free metrics registry with live export.
//!
//! The hot path records into lock-free primitives — [`Counter`] and
//! [`Gauge`] are single `AtomicU64`s, [`Histogram`] is a fixed array of
//! atomic buckets — while readers take a consistent [`Snapshot`] on
//! demand and render it as Prometheus text exposition
//! ([`Snapshot::to_prometheus`]) or JSON ([`Snapshot::to_json`]).
//!
//! Two registries coexist:
//! - [`global()`] holds process-lifetime monotone counters (qkernel
//!   dispatches, runtime step counts) that are safe to share across
//!   concurrent serve loops and tests.
//! - [`Obs::fresh()`] hands out an isolated registry + ring for one
//!   serve loop, so per-run accounting identities hold exactly even
//!   when many loops run in one process (as `cargo test` does).
//!
//! All recording is gated on a process-wide enable flag; see
//! [`ObsConfig::disabled`] for the escape hatch benchmarked in the
//! `obs` lane of `benches/hot_paths.rs`.

pub mod ring;
pub mod trace;

use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

pub use ring::{Event, Ring};
pub use trace::{Outcome, Stage, Trace, TraceReport};

/// Process-wide switch for all telemetry recording.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Telemetry configuration. The only knob today is the global enable
/// flag; `ObsConfig::disabled()` is the hot-path escape hatch whose
/// cost delta the `obs` bench lane measures.
#[derive(Clone, Copy, Debug)]
pub struct ObsConfig {
    pub enabled: bool,
}

impl ObsConfig {
    pub fn enabled() -> Self {
        ObsConfig { enabled: true }
    }

    pub fn disabled() -> Self {
        ObsConfig { enabled: false }
    }

    /// Install this configuration process-wide.
    pub fn install(self) {
        ENABLED.store(self.enabled, Ordering::Relaxed);
    }
}

/// True when recording is enabled (the default).
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotone event count. `inc`/`add` are single relaxed `fetch_add`s.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if is_enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value, stored as f64 bits in an
/// `AtomicU64` so readers never see a torn value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicU64::new(0f64.to_bits()))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        if is_enabled() {
            self.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram. Bucket `i` counts observations in
/// `(bounds[i-1], bounds[i]]`; one extra overflow bucket catches
/// everything above the last bound. Observation is two relaxed
/// `fetch_add`s plus a CAS loop folding the value into the f64 sum.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    /// `bounds` must be strictly increasing and non-empty.
    pub fn new(bounds: &[f64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Default latency buckets: exponential from 100µs to ~10s.
    pub fn latency() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1e-4;
        while b < 12.0 {
            bounds.push(b);
            b *= 2.0;
        }
        Histogram::new(&bounds)
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        if !is_enabled() {
            return;
        }
        let idx = self.bounds.partition_point(|&b| v > b);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            bounds: self.bounds.clone(),
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time copy of a [`Histogram`].
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistSnapshot {
    /// Cumulative counts per bucket (monotone by construction).
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .map(|&c| {
                acc += c;
                acc
            })
            .collect()
    }

    /// Estimate the `q`-quantile by linear interpolation inside the
    /// bucket holding the target rank. Assumes non-negative
    /// observations (bucket 0 interpolates from zero); the overflow
    /// bucket saturates at the last bound.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).max(1.0);
        let mut below = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let upto = below + c;
            if (upto as f64) >= target && c > 0 {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = match self.bounds.get(i) {
                    Some(&b) => b,
                    None => return *self.bounds.last().unwrap(),
                };
                let frac = (target - below as f64) / c as f64;
                return lo + frac * (hi - lo);
            }
            below = upto;
        }
        *self.bounds.last().unwrap()
    }
}

/// Exact-quantile summary metric: a mutex-wrapped
/// [`Summary`]. Locked per observation, so reserve it for
/// request-frequency events (latency per request), not step-frequency.
#[derive(Debug, Default)]
pub struct SummaryMetric(Mutex<Summary>);

impl SummaryMetric {
    pub fn new() -> Self {
        SummaryMetric(Mutex::new(Summary::new()))
    }

    pub fn observe(&self, v: f64) {
        if is_enabled() {
            self.0.lock().unwrap().add(v);
        }
    }

    /// Fold another summary in (exact merge, see `Summary::merge`).
    pub fn absorb(&self, other: &Summary) {
        if is_enabled() {
            self.0.lock().unwrap().merge(other);
        }
    }

    pub fn snapshot(&self) -> Summary {
        self.0.lock().unwrap().clone()
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    Summary(Arc<SummaryMetric>),
}

/// Named metric store. Registration is idempotent per full key
/// (`name{label="value",...}`): the first caller creates the metric,
/// later callers get the same `Arc` handle. The registry lock is only
/// taken at registration and snapshot time — handles record without it.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

/// Render a full metric key from a base name and label set.
pub fn key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut sorted: Vec<_> = labels.to_vec();
    sorted.sort_unstable();
    let body: Vec<String> =
        sorted.iter().map(|(k, v)| format!("{k}=\"{}\"", v.replace('"', "'"))).collect();
    format!("{name}{{{}}}", body.join(","))
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    fn entry<T>(
        &self,
        key: String,
        make: impl FnOnce() -> Metric,
        pick: impl FnOnce(&Metric) -> Option<Arc<T>>,
    ) -> Arc<T> {
        let mut map = self.metrics.lock().unwrap();
        let m = map.entry(key.clone()).or_insert_with(make);
        pick(m).unwrap_or_else(|| panic!("metric {key} registered with a different type"))
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counter_with(name, &[])
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        self.entry(
            key(name, labels),
            || Metric::Counter(Arc::new(Counter::new())),
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauge_with(name, &[])
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        self.entry(
            key(name, labels),
            || Metric::Gauge(Arc::new(Gauge::new())),
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        self.entry(
            key(name, &[]),
            || Metric::Histogram(Arc::new(Histogram::new(bounds))),
            |m| match m {
                Metric::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    pub fn summary(&self, name: &str) -> Arc<SummaryMetric> {
        self.entry(
            key(name, &[]),
            || Metric::Summary(Arc::new(SummaryMetric::new())),
            |m| match m {
                Metric::Summary(s) => Some(s.clone()),
                _ => None,
            },
        )
    }

    /// Consistent point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> Snapshot {
        let map = self.metrics.lock().unwrap();
        let mut snap = Snapshot::default();
        for (k, m) in map.iter() {
            match m {
                Metric::Counter(c) => {
                    snap.counters.insert(k.clone(), c.get());
                }
                Metric::Gauge(g) => {
                    snap.gauges.insert(k.clone(), g.get());
                }
                Metric::Histogram(h) => {
                    snap.histograms.insert(k.clone(), h.snapshot());
                }
                Metric::Summary(s) => {
                    snap.summaries.insert(k.clone(), s.snapshot());
                }
            }
        }
        snap
    }
}

/// Point-in-time copy of a [`Registry`], the single source every
/// exporter renders from: `/metrics`, `/v1/stats`, the end-of-run
/// `ServeStats`, and the bench-lane JSON export all read one of these.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistSnapshot>,
    pub summaries: BTreeMap<String, Summary>,
}

impl Snapshot {
    /// Counter value by full key, zero if absent.
    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Gauge value by full key, zero if absent.
    pub fn gauge(&self, key: &str) -> f64 {
        self.gauges.get(key).copied().unwrap_or(0.0)
    }

    /// Summary by name (cloned; empty if absent).
    pub fn summary(&self, key: &str) -> Summary {
        self.summaries.get(key).cloned().unwrap_or_default()
    }

    /// Merge another snapshot in (the other wins on key collisions);
    /// used to combine a serve-scoped registry with the process-global
    /// one for `/metrics`.
    pub fn merged(mut self, other: Snapshot) -> Snapshot {
        self.counters.extend(other.counters);
        self.gauges.extend(other.gauges);
        self.histograms.extend(other.histograms);
        self.summaries.extend(other.summaries);
        self
    }

    /// Prometheus text exposition (version 0.0.4): `# TYPE` comments,
    /// `_bucket{le=...}`/`_sum`/`_count` for histograms, and
    /// `{quantile="..."}` series for summaries.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut typed: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut type_line = |out: &mut String, key: &str, kind: &str| {
            let base = key.split('{').next().unwrap_or(key).to_string();
            if typed.insert(base.clone()) {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
            }
        };
        for (k, v) in &self.counters {
            type_line(&mut out, k, "counter");
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            type_line(&mut out, k, "gauge");
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in &self.histograms {
            type_line(&mut out, k, "histogram");
            let mut acc = 0u64;
            for (i, c) in h.counts.iter().enumerate() {
                acc += c;
                let le = match h.bounds.get(i) {
                    Some(b) => format!("{b}"),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{k}_bucket{{le=\"{le}\"}} {acc}\n"));
            }
            out.push_str(&format!("{k}_sum {}\n", h.sum));
            out.push_str(&format!("{k}_count {}\n", h.count));
        }
        for (k, s) in &self.summaries {
            type_line(&mut out, k, "summary");
            for q in [0.5, 0.95, 0.99] {
                out.push_str(&format!("{k}{{quantile=\"{q}\"}} {}\n", s.quantile(q)));
            }
            out.push_str(&format!("{k}_sum {}\n", s.sum()));
            out.push_str(&format!("{k}_count {}\n", s.count()));
        }
        out
    }

    /// JSON rendering for `/v1/stats` (via `util::json`): counters,
    /// gauges, summary quantiles, histogram quantiles.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
        let summaries = Json::Obj(
            self.summaries
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(s.count() as f64)),
                            ("sum", Json::Num(s.sum())),
                            ("p50", Json::Num(s.quantile(0.5))),
                            ("p95", Json::Num(s.quantile(0.95))),
                            ("p99", Json::Num(s.quantile(0.99))),
                            ("max", Json::Num(if s.count() == 0 { 0.0 } else { s.max() })),
                        ]),
                    )
                })
                .collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count as f64)),
                            ("sum", Json::Num(h.sum)),
                            ("p50", Json::Num(h.quantile(0.5))),
                            ("p95", Json::Num(h.quantile(0.95))),
                            ("p99", Json::Num(h.quantile(0.99))),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("summaries", summaries),
            ("histograms", histograms),
        ])
    }
}

/// Parse Prometheus text exposition back into `key -> value` (comments
/// skipped). Used by the CLI self-drive check and the e2e tests to
/// close the loop on what `/metrics` actually serves.
pub fn parse_text(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // The key may contain spaces inside label values; the value is
        // the final whitespace-separated token.
        if let Some(split) = line.rfind(' ') {
            let (k, v) = line.split_at(split);
            if let Ok(num) = v.trim().parse::<f64>() {
                out.insert(k.trim().to_string(), num);
            }
        }
    }
    out
}

/// Cheaply clonable handle bundling a registry with a postmortem ring.
#[derive(Clone)]
pub struct Obs {
    registry: Arc<Registry>,
    ring: Arc<Ring>,
}

impl Obs {
    /// An isolated registry + ring (one per serve loop / test).
    pub fn fresh() -> Obs {
        Obs { registry: Arc::new(Registry::new()), ring: Arc::new(Ring::new(256)) }
    }

    /// The process-global handle (qkernel / runtime counters).
    pub fn global() -> Obs {
        static GLOBAL: OnceLock<Obs> = OnceLock::new();
        GLOBAL.get_or_init(Obs::fresh).clone()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn ring(&self) -> &Ring {
        &self.ring
    }
}

impl Default for Obs {
    fn default() -> Self {
        Obs::fresh()
    }
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Obs({} metrics)", self.registry.metrics.lock().unwrap().len())
    }
}

/// Bump the process-global qkernel dispatch counter for one kernel
/// invocation. Handles are cached in a static table so the hot path
/// pays one `OnceLock` load plus one relaxed `fetch_add`.
pub fn note_qkernel_dispatch(kernel: usize, wl: u32) {
    const KERNELS: [&str; 5] =
        ["qmatmul", "qmatvec", "qmatvec_i32", "packed_matvec", "packed_matvec_fast"];
    const WL_LO: u32 = 2;
    const WL_HI: u32 = 8;
    static TABLE: OnceLock<Vec<Arc<Counter>>> = OnceLock::new();
    if !is_enabled() {
        return;
    }
    let table = TABLE.get_or_init(|| {
        let reg = Obs::global();
        let mut v = Vec::new();
        for k in KERNELS {
            for wl in WL_LO..=WL_HI {
                let wl_s = wl.to_string();
                let labels = [("kernel", k), ("wl", wl_s.as_str())];
                v.push(reg.registry().counter_with("qkernel_dispatch_total", &labels));
            }
        }
        v
    });
    let span = (WL_HI - WL_LO + 1) as usize;
    let wl_idx = (wl.clamp(WL_LO, WL_HI) - WL_LO) as usize;
    let idx = kernel.min(KERNELS.len() - 1) * span + wl_idx;
    table[idx].0.fetch_add(1, Ordering::Relaxed);
}

/// Kernel indices for [`note_qkernel_dispatch`].
pub mod kernels {
    pub const QMATMUL: usize = 0;
    pub const QMATVEC: usize = 1;
    pub const QMATVEC_I32: usize = 2;
    pub const PACKED_MATVEC: usize = 3;
    /// The fast integer tier's per-linear entry point
    /// (`PackedLinear::matvec_fast`) — counted separately from
    /// `packed_matvec` so `/metrics` shows the realized per-tier
    /// dispatch mix.
    pub const PACKED_MATVEC_FAST: usize = 4;
}

/// The [`ObsConfig`] gate is process-global, so a unit test that flips
/// it could race a concurrently running test that asserts exact
/// recorded counts. Flippers hold the write side for their disabled
/// window; exactness tests hold the read side while they record.
#[cfg(test)]
pub fn test_gate() -> &'static std::sync::RwLock<()> {
    static GATE: OnceLock<std::sync::RwLock<()>> = OnceLock::new();
    GATE.get_or_init(|| std::sync::RwLock::new(()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip_and_idempotent_registration() {
        let _gate = test_gate().read().unwrap_or_else(|e| e.into_inner());
        let obs = Obs::fresh();
        let c1 = obs.registry().counter("requests_total");
        let c2 = obs.registry().counter("requests_total");
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3, "both handles hit the same counter");
        let g = obs.registry().gauge_with("depth", &[("lane", "a")]);
        g.set(4.5);
        let snap = obs.registry().snapshot();
        assert_eq!(snap.counter("requests_total"), 3);
        assert_eq!(snap.gauge("depth{lane=\"a\"}"), 4.5);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let _gate = test_gate().read().unwrap_or_else(|e| e.into_inner());
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 1.5, 3.0, 9.0] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert!((s.sum - 15.5).abs() < 1e-9);
        assert_eq!(s.counts, vec![1, 2, 1, 1]);
        assert_eq!(s.cumulative(), vec![1, 3, 4, 5]);
        // Median rank lands in bucket (1, 2]; estimate interpolates there.
        let q = s.quantile(0.5);
        assert!((1.0..=2.0).contains(&q), "median {q} should fall in (1,2]");
        // Overflow bucket saturates at the top bound.
        assert_eq!(s.quantile(1.0), 4.0);
    }

    #[test]
    fn prometheus_text_parses_back_to_the_same_values() {
        let _gate = test_gate().read().unwrap_or_else(|e| e.into_inner());
        let obs = Obs::fresh();
        obs.registry().counter_with("x_total", &[("k", "v")]).add(7);
        obs.registry().gauge("depth").set(2.5);
        obs.registry().histogram("lat_seconds", &[0.1, 1.0]).observe(0.05);
        obs.registry().summary("sum_seconds").observe(0.3);
        let text = obs.registry().snapshot().to_prometheus();
        let parsed = parse_text(&text);
        assert_eq!(parsed["x_total{k=\"v\"}"], 7.0);
        assert_eq!(parsed["depth"], 2.5);
        assert_eq!(parsed["lat_seconds_count"], 1.0);
        assert_eq!(parsed["lat_seconds_bucket{le=\"0.1\"}"], 1.0);
        assert_eq!(parsed["lat_seconds_bucket{le=\"+Inf\"}"], 1.0);
        assert_eq!(parsed["sum_seconds_count"], 1.0);
        assert_eq!(parsed["sum_seconds{quantile=\"0.5\"}"], 0.3);
    }

    #[test]
    fn disabled_config_suppresses_recording() {
        // Write side: no exactness test records while the gate is down.
        let _gate = test_gate().write().unwrap_or_else(|e| e.into_inner());
        let obs = Obs::fresh();
        let c = obs.registry().counter("muted_total");
        let h = obs.registry().histogram("muted_seconds", &[1.0]);
        ObsConfig::disabled().install();
        c.inc();
        h.observe(0.5);
        ObsConfig::enabled().install();
        assert_eq!(c.get(), 0, "disabled counter must not move");
        assert_eq!(h.snapshot().count, 0, "disabled histogram must not move");
        c.inc();
        assert_eq!(c.get(), 1, "re-enabled counter records again");
    }

    #[test]
    fn snapshot_merge_prefers_other_on_collision() {
        let _gate = test_gate().read().unwrap_or_else(|e| e.into_inner());
        let a = Obs::fresh();
        let b = Obs::fresh();
        a.registry().counter("shared_total").add(1);
        a.registry().counter("only_a_total").add(2);
        b.registry().counter("shared_total").add(10);
        let merged = a.registry().snapshot().merged(b.registry().snapshot());
        assert_eq!(merged.counter("shared_total"), 10);
        assert_eq!(merged.counter("only_a_total"), 2);
    }
}
