//! Criterion-style bench harness (the image vendors no criterion crate).
//!
//! `cargo bench` runs the `[[bench]]` targets with `harness = false`; each
//! target builds a [`Bench`] suite, registers closures, and the harness
//! does warmup + timed sampling and prints mean/median/stddev/throughput.
//! Honors the standard `cargo bench <filter>` argument.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

/// Bench suite runner.
pub struct Bench {
    filter: Option<String>,
    warmup_iters: usize,
    min_samples: usize,
    max_samples: usize,
    target_time_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // `cargo bench foo` passes "foo" plus `--bench`; take the first
        // non-flag arg as a substring filter.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench {
            filter,
            warmup_iters: 2,
            min_samples: 5,
            max_samples: 30,
            target_time_s: 2.0,
            results: Vec::new(),
        }
    }

    /// Quick profile for smoke runs (fewer samples).
    pub fn quick(mut self) -> Bench {
        self.warmup_iters = 1;
        self.min_samples = 3;
        self.max_samples = 8;
        self.target_time_s = 0.5;
        self
    }

    /// Minimal profile for expensive end-to-end benches (figure
    /// regenerations run seconds-to-minutes per sample).
    pub fn minimal(mut self) -> Bench {
        self.warmup_iters = 0;
        self.min_samples = 2;
        self.max_samples = 2;
        self.target_time_s = 0.0;
        self
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Register and run one benchmark.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        let t_suite = Instant::now();
        while s.count() < self.min_samples
            || (s.count() < self.max_samples
                && t_suite.elapsed().as_secs_f64() < self.target_time_s)
        {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult {
            name: name.to_string(),
            samples: s.count(),
            mean_s: s.mean(),
            median_s: s.median(),
            stddev_s: s.stddev(),
            min_s: s.min(),
        };
        println!(
            "{:<44} {:>10.4} ms/iter (median {:.4}, sd {:.4}, n={})",
            r.name,
            r.mean_s * 1e3,
            r.median_s * 1e3,
            r.stddev_s * 1e3,
            r.samples
        );
        self.results.push(r);
    }

    /// Benchmark with a throughput annotation (items/sec at the mean).
    pub fn bench_throughput(&mut self, name: &str, items: u64, f: impl FnMut()) {
        let before = self.results.len();
        self.bench(name, f);
        if self.results.len() > before {
            let r = &self.results[before];
            println!(
                "{:<44} {:>10.1} items/s",
                format!("  -> {}", r.name),
                items as f64 / r.mean_s
            );
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(&self) {
        println!("\n{} benchmarks run.", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench::new().quick();
        b.filter = None;
        let mut count = 0u64;
        b.bench("noop", || {
            count += 1;
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].samples >= 3);
        assert!(count >= 4); // warmup + samples
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench::new().quick();
        b.filter = Some("match-me".to_string());
        b.bench("other", || {});
        assert!(b.results().is_empty());
        b.bench("match-me-too", || {});
        assert_eq!(b.results().len(), 1);
    }
}
