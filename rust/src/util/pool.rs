//! Scoped thread pool for data-parallel compression jobs.
//!
//! The image vendors no rayon/tokio; the coordinator parallelizes per-layer
//! compression (Algorithm 1 is independent across weight matrices) with
//! `std::thread::scope` work-stealing over an atomic index. On the 1-core
//! CI image this degrades gracefully to sequential execution.
//!
//! Result slots are written lock-free: the atomic work-distribution index
//! hands every slot index to exactly one worker, so each `Option<T>` slot
//! has a single writer and needs no mutex — the scope join publishes the
//! writes before the collection pass reads them.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (min(available_parallelism, cap)).
pub fn default_workers(cap: usize) -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(cap).max(1)
}

/// Shared pointer into the slot vector. Safety rests on the caller handing
/// each index to at most one writer (the atomic counter guarantees that).
struct SlotPtr<T>(*mut Option<T>);

unsafe impl<T: Send> Sync for SlotPtr<T> {}

/// Apply `f` to every index in `0..n`, in parallel, collecting results in
/// index order. `f` must be `Sync`; results are written lock-free into a
/// preallocated slot vector (one writer per slot, no per-item mutex).
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n).max(1);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let slot_ptr = SlotPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let slot_ptr = &slot_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: `fetch_add` yields each `i < n` exactly once, so
                // this thread is the only writer of slot `i`; the slot was
                // initialized to `None` before the scope started, and the
                // scope's join synchronizes the write with the read below.
                unsafe { *slot_ptr.0.add(i) = Some(v) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker failed to fill slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 8, |i| i + 1), vec![1]);
    }

    #[test]
    fn sequential_fallback_matches() {
        let a = par_map(37, 1, |i| i as f64 * 1.5);
        let b = par_map(37, 3, |i| i as f64 * 1.5);
        assert_eq!(a, b);
    }

    #[test]
    fn heap_results_survive_lock_free_slots() {
        // Non-Copy results with drops exercise slot write + move-out.
        let out = par_map(64, 4, |i| vec![i; i % 5 + 1]);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.len(), i % 5 + 1);
            assert!(v.iter().all(|&x| x == i));
        }
    }

    #[test]
    fn many_more_items_than_workers() {
        let out = par_map(1000, 7, |i| i as u64 + 1);
        assert_eq!(out.iter().sum::<u64>(), 500_500);
    }
}
