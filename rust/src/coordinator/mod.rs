//! Experiment coordinator: glues compression, SRA, evaluation and DSE.
//!
//! The coordinator owns the PJRT engine, the per-pair models and corpora,
//! and an evaluation cache; everything the figure runners ([`figures`])
//! and the examples do goes through it. Per-layer compression jobs fan out
//! on the thread pool; BLEU evaluations are memoized by configuration
//! fingerprint (the SRA search revisits allocations).

pub mod figures;
mod methods;
pub mod report;
mod serve;

pub use methods::{CompressedModel, Method};
pub use serve::{serve_bank, serve_demo};

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::compress::CompressedLinear;
use crate::config::ExpConfig;
use crate::eval::{evaluate_bleu, Corpus};
use crate::model::{Manifest, PairModel};
use crate::runtime::{Engine, Mode, TranslateSession};

/// Orchestrates the full ITERA-LLM pipeline against the built artifacts.
pub struct Coordinator {
    pub manifest: Manifest,
    pub engine: Engine,
    pub cfg: ExpConfig,
    models: BTreeMap<String, PairModel>,
    corpora: BTreeMap<String, Corpus>,
    calib: BTreeMap<String, Corpus>,
    bleu_cache: Mutex<HashMap<u64, f64>>,
}

impl Coordinator {
    /// Load manifest, weights and corpora for every trained pair and
    /// create the PJRT engine.
    pub fn new(cfg: ExpConfig) -> Result<Coordinator> {
        let manifest = Manifest::load(Manifest::default_dir())
            .context("loading artifacts (run `make artifacts`)")?;
        let engine = Engine::cpu()?;
        let mut models = BTreeMap::new();
        let mut corpora = BTreeMap::new();
        let mut calib = BTreeMap::new();
        for (pair, info) in &manifest.pairs {
            models.insert(pair.clone(), PairModel::load(&manifest, pair)?);
            corpora.insert(pair.clone(), Corpus::load(&info.corpus)?);
            calib.insert(pair.clone(), Corpus::load(&info.calib)?);
        }
        Ok(Coordinator {
            manifest,
            engine,
            cfg,
            models,
            corpora,
            calib,
            bleu_cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn model(&self, pair: &str) -> &PairModel {
        &self.models[pair]
    }

    pub fn pairs(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Compress every linear of `pair` with `method` (parallel per layer).
    pub fn compress(&self, pair: &str, method: &Method) -> CompressedModel {
        methods::compress_model(self, pair, method)
    }

    /// BLEU of a compressed model on the held-out test set.
    pub fn bleu_test(&self, pair: &str, cm: &CompressedModel) -> Result<f64> {
        self.bleu_on(pair, cm, &self.corpora[pair], self.cfg.eval_sentences)
    }

    /// BLEU on the calibration subset (the SRA oracle), memoized.
    pub fn bleu_calib(&self, pair: &str, cm: &CompressedModel) -> Result<f64> {
        let key = cm.fingerprint(pair);
        if let Some(&v) = self.bleu_cache.lock().unwrap().get(&key) {
            return Ok(v);
        }
        let v = self.bleu_on(pair, cm, &self.calib[pair], self.cfg.calib_sentences)?;
        self.bleu_cache.lock().unwrap().insert(key, v);
        Ok(v)
    }

    fn bleu_on(
        &self,
        pair: &str,
        cm: &CompressedModel,
        corpus: &Corpus,
        limit: usize,
    ) -> Result<f64> {
        let mode = cm.mode();
        let session = TranslateSession::new(&self.engine, &self.manifest, mode)?;
        let bank = session.build_bank(&self.models[pair], &cm.layers, cm.act_wl)?;
        let d = evaluate_bleu(&session, &bank, corpus, &self.manifest.model, limit)?;
        Ok(d.score)
    }

    /// FP32 reference BLEU (uncompressed, FP32 activations).
    pub fn bleu_fp32(&self, pair: &str) -> Result<f64> {
        let session = TranslateSession::new(&self.engine, &self.manifest, Mode::Dense)?;
        let bank = session.build_bank(&self.models[pair], &BTreeMap::new(), None)?;
        let d = evaluate_bleu(
            &session,
            &bank,
            &self.corpora[pair],
            &self.manifest.model,
            self.cfg.eval_sentences,
        )?;
        Ok(d.score)
    }

    /// Compress a single layer by manifest index (SRA inner loop).
    pub fn compress_layer(
        &self,
        pair: &str,
        idx: usize,
        method: &Method,
        rank: usize,
    ) -> CompressedLinear {
        let l = &self.manifest.linears[idx];
        methods::compress_one(self.models[pair].linear(&l.name), method, rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coordinator() -> Option<Coordinator> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Coordinator::new(ExpConfig::fast()).unwrap())
    }

    #[test]
    fn quant_only_pipeline_end_to_end() {
        let Some(c) = coordinator() else { return };
        let cm = c.compress("en-de", &Method::QuantOnly { wl: 8 });
        assert_eq!(cm.layers.len(), c.manifest.linears.len());
        let bleu = c.bleu_test("en-de", &cm).unwrap();
        assert!(bleu > 80.0, "W8A8 BLEU {bleu}");
        let (ratio, _nops) = cm.cost(&c.manifest, 512);
        assert!((ratio - 4.0).abs() < 0.3, "W8 ratio {ratio}");
    }

    #[test]
    fn calib_cache_hits() {
        let Some(c) = coordinator() else { return };
        let cm = c.compress("en-de", &Method::QuantOnly { wl: 6 });
        let a = c.bleu_calib("en-de", &cm).unwrap();
        let t0 = std::time::Instant::now();
        let b = c.bleu_calib("en-de", &cm).unwrap();
        assert_eq!(a, b);
        assert!(t0.elapsed().as_millis() < 50, "second call must be cached");
    }
}
