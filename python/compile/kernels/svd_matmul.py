"""Pallas cascade SVD-matmul kernel — the paper's Cascade SVD MatMul engine.

Computes ``y = (x @ w1) @ w2`` (Eq. 3) without reconstructing ``W``. Mirrors
the Cascade engine of Fig. 6 (right): two back-to-back matmul stages sharing
the ``M_t`` tiling factor, with the entire ``M_t × R`` intermediate tile held
on-chip between the stages — here a VMEM scratch buffer, on the FPGA a BRAM
buffer. The grid walks ``(M/M_t, N/N_t)``; stage one runs once per M-row of
the grid (``N``-index 0) and is then reused for every N-tile, which is
exactly the reuse the on-chip intermediate buys the hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant_matmul import _pick_block


def _cascade_kernel(x_ref, w1_ref, w2_ref, o_ref, t_ref):
    """One (mt, nt) grid step of the cascade engine.

    ``t_ref`` is the VMEM scratch holding the ``M_t × R`` intermediate
    (``X @ W1``) tile; it is produced when the N-grid index is 0 and
    consumed by every stage-two N-tile of the same M-tile.
    """
    @pl.when(pl.program_id(1) == 0)
    def _stage_one():
        t_ref[...] = jnp.dot(
            x_ref[...], w1_ref[...], preferred_element_type=jnp.float32
        )

    o_ref[...] = jnp.dot(
        t_ref[...], w2_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def cascade_matmul(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    *,
    block_m: int = 64,
    block_n: int = 64,
) -> jnp.ndarray:
    """Cascade ``y = (x @ w1) @ w2``; ``w1: [K, R]``, ``w2: [R, N]``.

    ``R`` is the *padded* decomposition rank (``r_max``): the Rust
    coordinator zero-pads quantized rank-``r`` factors up to ``r_max`` so a
    single compiled artifact serves every rank allocation (DESIGN.md).
    Zero columns/rows contribute nothing to either stage, so the result
    equals the true rank-``r`` product.
    """
    m, k = x.shape
    k2, r = w1.shape
    r2, n = w2.shape
    assert k == k2 and r == r2, (x.shape, w1.shape, w2.shape)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _cascade_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pl.MemorySpace.ANY(shape=(bm, r), dtype=jnp.float32)],
        interpret=True,
    )(x, w1, w2)
