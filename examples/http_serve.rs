//! Network serving demo: the dependency-free HTTP/1.1 front end over the
//! continuous batcher, self-driven by the seeded load generator.
//!
//! ```bash
//! cargo run --release --example http_serve [-- <addr> <requests>]
//! ```
//!
//! Binds `<addr>` (default `127.0.0.1:0` — an ephemeral port, printed at
//! startup) and serves `POST /v1/translate`, `GET /healthz` and
//! `POST /v1/shutdown` from a W8A8-compressed model on the pure-Rust
//! native engine — `std::net` only, no HTTP crate, no PJRT, no Python.
//! Responses are bit-identical to in-process serving; add
//! `"stream": true` to a translate body for chunked incremental tokens.
//!
//! With `<requests> > 0` (default 64) a seeded open-loop Poisson client
//! drives the server, then flips the shutdown signal; the server drains
//! gracefully and both ledgers — the server's `ServeStats` and the
//! client's `LoadReport` — are printed and cross-checked. Pass `0` to
//! leave the server up until someone POSTs `/v1/shutdown`.
//!
//! Works in any checkout: real artifacts when `ITERA_ARTIFACTS` points
//! at a manifest, the hermetic testkit tiny model otherwise.

use anyhow::Result;
use itera_llm::coordinator::{self, Method, ServeConfig, ShutdownSignal};
use itera_llm::model::{Manifest, PairModel};
use itera_llm::runtime::Mode;
use itera_llm::server::loadgen::{run_loadgen, LoadGenConfig};
use itera_llm::server::{serve_http, HttpConfig};
use itera_llm::testkit::tinymodel;
use itera_llm::util::pool::default_workers;

fn main() -> Result<()> {
    let addr = std::env::args().nth(1).unwrap_or_else(|| "127.0.0.1:0".to_string());
    let requests: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    // Real artifacts when present, the hermetic tiny model otherwise —
    // the demo runs in any checkout.
    let (tmp, manifest) = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => (None, m),
        Err(_) => {
            let (dir, m) = tinymodel::generate_in_temp("http_serve_demo", 0x11775)?;
            println!("(no artifacts found; serving the hermetic tiny model)");
            (Some(dir), m)
        }
    };
    let pair = manifest
        .pairs
        .keys()
        .next()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("manifest registers no language pairs"))?;
    let model = PairModel::load(&manifest, &pair)?;
    let workers = default_workers(8);
    let weights: Vec<_> = manifest.linears.iter().map(|l| model.linear(&l.name)).collect();
    let cm = coordinator::compress_model_from(
        &manifest.linears,
        &weights,
        &Method::QuantOnly { wl: 8 },
        None,
        workers,
    );
    let backend = cm.native_backend_mode(&manifest, &model, Mode::Dense, workers)?;

    let listener = std::net::TcpListener::bind(&addr)?;
    let local = listener.local_addr()?;
    println!("serving {pair} on http://{local}");
    println!("  POST /v1/translate  {{\"tokens\": [..], \"stream\": true?}}");
    println!("  GET  /healthz       POST /v1/shutdown");

    let shutdown = ShutdownSignal::new();
    let mut serve_cfg = ServeConfig::new(manifest.model.eval_batch);
    serve_cfg.shutdown = Some(shutdown.clone());

    // Self-drive: the seeded open-loop Poisson client, then a graceful
    // drain once its last response lands.
    let client = (requests > 0).then(|| {
        let cfg = LoadGenConfig {
            connections: 4,
            requests,
            rate: 200.0,
            len_range: (2, manifest.model.seq_len.saturating_sub(2).max(2)),
            vocab: manifest.model.vocab as i32,
            ..LoadGenConfig::default()
        };
        std::thread::spawn(move || {
            let report = run_loadgen(local, &cfg);
            shutdown.drain();
            report
        })
    });

    let stats = serve_http(&backend, listener, &manifest.model, HttpConfig::new(serve_cfg))?;
    println!(
        "served {} / received {} (shed {}, expired {}, cancelled {}, faulted {})",
        stats.served, stats.received, stats.shed, stats.expired, stats.cancelled, stats.faulted,
    );
    println!(
        "  {:.1} tok/s; latency p50 {:.2} ms p95 {:.2} ms (queue-wait p95 {:.2} ms)",
        stats.tokens_per_s(),
        stats.latency.quantile(0.5) * 1e3,
        stats.latency.quantile(0.95) * 1e3,
        stats.queue_wait.quantile(0.95) * 1e3,
    );
    anyhow::ensure!(stats.is_balanced(), "serve accounting must balance: {stats:?}");
    if let Some(c) = client {
        let report = c.join().map_err(|_| anyhow::anyhow!("load generator panicked"))??;
        report.print("loadgen");
        anyhow::ensure!(report.ok > 0, "self-drive must answer at least one request");
    }
    if let Some(dir) = tmp {
        std::fs::remove_dir_all(&dir).ok();
    }
    Ok(())
}
