"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and tile sizes; every kernel must match its
``ref.py`` oracle to float tolerance under interpret mode.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cascade_matmul, fake_quant, quant_matmul
from compile.kernels.ref import cascade_ref, fake_quant_ref, matmul_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

dims = st.integers(min_value=1, max_value=48)
blocks = st.sampled_from([1, 2, 4, 8, 16, 64])


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, bk=blocks, seed=st.integers(0, 2**16))
def test_quant_matmul_matches_oracle(m, k, n, bm, bn, bk, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1)
    got = quant_matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    want = matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@given(m=dims, k=dims, r=st.integers(1, 24), n=dims, bm=blocks, bn=blocks,
       seed=st.integers(0, 2**16))
def test_cascade_matmul_matches_oracle(m, k, r, n, bm, bn, seed):
    x = rand((m, k), seed)
    w1 = rand((k, r), seed + 1)
    w2 = rand((r, n), seed + 2)
    got = cascade_matmul(x, w1, w2, block_m=bm, block_n=bn)
    want = cascade_ref(x, w1, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


@given(m=dims, n=dims, scale=st.floats(1e-3, 10.0), wl=st.integers(2, 8),
       seed=st.integers(0, 2**16))
def test_fake_quant_matches_oracle(m, n, scale, wl, seed):
    x = rand((m, n), seed) * 3.0
    levels = float(2 ** (wl - 1) - 1)
    got = fake_quant(x, scale, levels)
    want = fake_quant_ref(x, scale, levels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_fake_quant_levels_zero_is_identity():
    x = rand((8, 8), 0)
    got = np.asarray(fake_quant(x, 0.5, 0.0))
    np.testing.assert_allclose(got, x)


def test_fake_quant_output_on_grid():
    x = rand((16, 8), 1)
    s, lv = 0.07, 7.0
    q = np.asarray(fake_quant(x, s, lv))
    ints = q / s
    np.testing.assert_allclose(ints, np.round(ints), atol=1e-4)
    assert np.all(np.abs(ints) <= lv + 1e-4)


def test_cascade_zero_padding_invariant():
    """Zero-padded ranks must not change the product (the runtime trick)."""
    x = rand((8, 16), 2)
    w1 = rand((16, 5), 3)
    w2 = rand((5, 12), 4)
    w1p = np.zeros((16, 16), np.float32)
    w1p[:, :5] = w1
    w2p = np.zeros((16, 12), np.float32)
    w2p[:5] = w2
    a = np.asarray(cascade_matmul(x, w1, w2))
    b = np.asarray(cascade_matmul(x, w1p, w2p))
    np.testing.assert_allclose(a, b, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (64, 64, 64), (3, 65, 7)])
def test_quant_matmul_shape_edges(m, k, n):
    x = rand((m, k), 5)
    w = rand((k, n), 6)
    got = np.asarray(quant_matmul(x, w))
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, np.asarray(matmul_ref(x, w)), atol=1e-4, rtol=1e-4)
