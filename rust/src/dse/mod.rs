//! Design Space Exploration (§VII): hardware sweeps, Pareto extraction,
//! and the model x hardware co-design loop of Fig. 2.

mod pareto;
mod sweep;

pub use pareto::{pareto_front, ParetoPoint};
pub use sweep::{
    best_design_for_layer, best_design_for_model, enumerate_tiles, sweep_engines, DesignPoint,
    LayerWork,
};
